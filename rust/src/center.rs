//! Computation-center node: a persistent, session-multiplexed worker.
//!
//! A center is one of the w independent share holders. It serves every
//! active study session at once: per `(session, iteration)` it folds
//! each institution's submission into a streaming
//! [`SecureAccumulator`] (secure addition — Algorithm 2), and when the
//! coordinator requests the aggregate after all S institutions have
//! submitted, it answers with its share of the GLOBAL sums, tagged
//! with the session id. It never holds, sees, or transmits a
//! reconstructable view of any single institution's summaries — that
//! is the whole point of the scheme, and
//! `attack::below_threshold_views_are_uniform` verifies it.
//!
//! Share-domain folds (gradient, deviance, full-mode Hessian) are
//! exact field additions, so arrival order cannot change the result.
//! The pragmatic-mode plaintext Hessian is f64, where summation order
//! DOES move the last ulp — so the lead center buffers plaintext
//! contributions and folds them in institution-id order at response
//! time. That makes every aggregate, and therefore every fitted β,
//! bit-identical regardless of how submissions interleave — the
//! property the session engine's concurrent-equals-sequential
//! guarantee rests on.

use crate::protocol::{HessianPayload, Message, NodeId, SessionId};
use crate::secure::SecureAccumulator;
use crate::session::SessionRegistry;
use crate::transport::Endpoint;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Everything a persistent center worker needs.
pub struct CenterWorkerConfig {
    pub center_id: u16,
    /// Session lookup: dimension, mode, busy-telemetry cells.
    pub registry: Arc<SessionRegistry>,
    /// Gauge of live per-session states on this worker, maintained on
    /// every open/close — the engine's leak gate reads it to PROVE that
    /// acknowledged teardown freed everything.
    pub live_sessions: Arc<AtomicUsize>,
}

/// Per-iteration aggregation state within one session.
struct IterState {
    acc: SecureAccumulator,
    /// Pragmatic-mode lead center only: plaintext Hessian contributions
    /// buffered per institution, folded in id order at response time
    /// (f64 addition is order-sensitive; share folds above are not).
    h_plain_pending: Vec<(u16, Vec<f64>)>,
    /// Institutions already folded this iteration. Makes the fold
    /// idempotent: a duplicated submission frame (fault injection, or
    /// a pre-suspension straggler racing a replayed round) is ignored
    /// instead of double-counted into the accumulator.
    seen: Vec<u16>,
    /// Pending aggregate request: expected submission count.
    pending_request: Option<u16>,
    /// This iteration aggregates DP noise shares, not Newton
    /// statistics. No Hessian exists on that round in ANY mode, so the
    /// response must carry `HessianPayload::Absent` even from the
    /// pragmatic lead center (whose plaintext-count check would
    /// otherwise reject the round). Set by the first
    /// `DpNoiseSubmission` folded into this iteration.
    dp: bool,
}

/// Per-session center state.
struct CenterSession {
    /// Length of the shared statistic vector (`SessionSpec::stat_len`):
    /// d for Newton fits, d+1 for score screens. The center sums shares
    /// obliviously — it sizes the accumulator without knowing which
    /// statistic it is aggregating.
    d: usize,
    packed_h: usize,
    full_security: bool,
    /// Score-screen session: no Hessian exists in ANY mode, so the
    /// response carries `HessianPayload::Absent` even from the lead
    /// center (whose pragmatic-mode plaintext-count check would
    /// otherwise reject the round).
    screen: bool,
    /// This session's secure-aggregation busy counter for this center.
    busy_ns: Arc<AtomicU64>,
    iters: HashMap<u32, IterState>,
    /// Answered iterations' states, zeroed and ready for reuse — the
    /// per-(session, iteration) fold state allocates only until the
    /// session's steady concurrency is reached, then recycles.
    free: Vec<IterState>,
}

impl CenterSession {
    /// A blank per-iteration state, recycled from the pool when one is
    /// available. The share-domain accumulator carries the pragmatic
    /// plaintext Hessian in `h_plain_pending` instead, so `packed_h`
    /// matters only in full mode.
    fn take_iter_state(&mut self) -> IterState {
        match self.free.pop() {
            Some(st) => st, // already reset when retired
            None => IterState {
                acc: SecureAccumulator::new(
                    self.d,
                    if self.full_security { self.packed_h } else { 0 },
                    self.full_security,
                ),
                h_plain_pending: Vec::new(),
                seen: Vec::new(),
                pending_request: None,
                dp: false,
            },
        }
    }

    /// Return an answered iteration's state to the pool, zeroed.
    fn recycle_iter_state(&mut self, mut st: IterState) {
        st.acc.reset();
        st.h_plain_pending.clear();
        st.seen.clear();
        st.pending_request = None;
        st.dp = false;
        self.free.push(st);
    }
}

/// Run the persistent center event loop until `Shutdown`.
///
/// Owns its endpoint; spawn on a dedicated thread. Per-session errors
/// are reported to the coordinator as session-tagged `NodeError`s and
/// tear down only that session's state. `SessionClose`/`Abort` frames
/// free the session's state and are ALWAYS acknowledged with a
/// `CloseAck` — even for sessions this center never opened (or already
/// dropped after an error), so the driver's drain can never hang on an
/// already-clean worker.
pub fn run_center_worker(cfg: CenterWorkerConfig, ep: Endpoint) -> anyhow::Result<()> {
    let mut sessions: HashMap<SessionId, CenterSession> = HashMap::new();
    let drop_session = |sessions: &mut HashMap<SessionId, CenterSession>, session| {
        if sessions.remove(&session).is_some() {
            cfg.live_sessions.fetch_sub(1, Ordering::Relaxed);
        }
    };
    loop {
        let (from, session, msg) = ep.recv_session()?;
        match msg {
            Message::Shutdown => return Ok(()),
            Message::SessionReopen { .. } => {
                // A suspended session is about to replay its current
                // round: discard every trace of the interrupted
                // attempt (partial accumulators included) so the
                // replay re-opens lazily from the registry spec.
                // Idempotent — never-opened sessions are a no-op, so
                // duplicated reopen frames are harmless. No ack: the
                // replayed round's own traffic follows on the same
                // FIFO mailbox, behind this frame.
                drop_session(&mut sessions, session);
            }
            Message::SessionClose { .. } | Message::Abort { .. } => {
                // State is freed BEFORE the ack goes out: once the
                // driver has every ack, zero-leak is a fact, not a race.
                // The registry entry is purged too (remote mode gives
                // every process its own registry copy; in shared mode
                // the driver's own purge at retirement makes this a
                // benign double-remove). NOT done on `SessionReopen` —
                // the spec must survive for the replay to re-open from.
                drop_session(&mut sessions, session);
                cfg.registry.remove(session);
                let _ = ep.send_session(
                    NodeId::Coordinator,
                    session,
                    &Message::CloseAck {
                        node: cfg.center_id,
                        is_center: true,
                    },
                );
            }
            other => {
                if let Err(e) = handle_message(&cfg, &ep, &mut sessions, session, from, other) {
                    drop_session(&mut sessions, session);
                    let _ = ep.send_session(
                        NodeId::Coordinator,
                        session,
                        &Message::NodeError {
                            node: cfg.center_id,
                            is_center: true,
                            error: format!("{e:#}"),
                        },
                    );
                }
            }
        }
    }
}

fn handle_message(
    cfg: &CenterWorkerConfig,
    ep: &Endpoint,
    sessions: &mut HashMap<SessionId, CenterSession>,
    session: SessionId,
    from: NodeId,
    msg: Message,
) -> anyhow::Result<()> {
    // Lazily open the session from the registry.
    if !sessions.contains_key(&session) {
        let spec = cfg
            .registry
            .get(session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session}"))?;
        anyhow::ensure!(
            (cfg.center_id as usize) < spec.num_centers(),
            "center {} not part of session {session}",
            cfg.center_id
        );
        let d = spec.d();
        let screen = spec.screen.is_some();
        sessions.insert(
            session,
            CenterSession {
                d: spec.stat_len(),
                packed_h: if screen { 0 } else { d * (d + 1) / 2 },
                // Screens never carry Hessians, whatever the mode.
                full_security: spec.full_security && !screen,
                screen,
                busy_ns: spec.center_busy_ns[cfg.center_id as usize].clone(),
                iters: HashMap::new(),
                free: Vec::new(),
            },
        );
        cfg.live_sessions.fetch_add(1, Ordering::Relaxed);
    }
    let cs = sessions.get_mut(&session).unwrap();

    match msg {
        Message::ShareSubmission {
            iter,
            institution,
            hessian,
            g_share,
            dev_share,
        } => {
            anyhow::ensure!(
                matches!(from, NodeId::Institution(_)),
                "submission from non-institution {from}"
            );
            let (packed_h, full) = (cs.packed_h, cs.full_security);
            if !cs.iters.contains_key(&iter) {
                let st = cs.take_iter_state();
                cs.iters.insert(iter, st);
            }
            let st = cs.iters.get_mut(&iter).unwrap();
            // Idempotent fold: a duplicate (institution, iter) frame
            // carries bit-identical content (shares are a pure
            // function of the spec's derived seed), so it is dropped
            // rather than double-folded.
            if st.seen.contains(&institution) {
                return Ok(());
            }
            st.seen.push(institution);
            // Busy time is recorded BEFORE any send: the response's
            // arrival at the driver is what ends a round, so counter
            // updates must happen-before it for the per-session
            // metrics read at session completion to be complete.
            let t = std::time::Instant::now();
            match hessian {
                HessianPayload::Plain(h) => {
                    anyhow::ensure!(!full, "plaintext hessian in full mode");
                    anyhow::ensure!(h.len() == packed_h, "hessian length mismatch");
                    st.h_plain_pending.push((institution, h));
                    st.acc.fold(&g_share, dev_share, &HessianPayload::Absent)?;
                }
                other => st.acc.fold(&g_share, dev_share, &other)?,
            }
            cs.busy_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            maybe_respond(cfg, ep, session, cs, iter)?;
        }
        Message::DpNoiseSubmission {
            iter,
            institution,
            noise_share,
            mask_share,
        } => {
            anyhow::ensure!(
                matches!(from, NodeId::Institution(_)),
                "dp noise submission from non-institution {from}"
            );
            anyhow::ensure!(
                noise_share.len() == cs.d,
                "dp noise share length {} != {}",
                noise_share.len(),
                cs.d
            );
            if !cs.iters.contains_key(&iter) {
                let st = cs.take_iter_state();
                cs.iters.insert(iter, st);
            }
            let st = cs.iters.get_mut(&iter).unwrap();
            // Same idempotence argument as the Newton fold: the noise
            // share is a pure function of the spec's derived seed
            // streams, so a duplicated frame (fault injection, crash
            // replay) is bit-identical and dropped, never double-added.
            if st.seen.contains(&institution) {
                return Ok(());
            }
            st.seen.push(institution);
            st.dp = true;
            let t = std::time::Instant::now();
            // Fold directly in the share domain. `SecureAccumulator::
            // fold` would demand a Hessian payload in full mode, and a
            // DP noise round never carries one in any mode.
            crate::secure::secure_add(&mut st.acc.g, &noise_share);
            st.acc.dev = st.acc.dev + mask_share;
            st.acc.count += 1;
            cs.busy_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            maybe_respond(cfg, ep, session, cs, iter)?;
        }
        Message::AggregateRequest { iter, expected } => {
            anyhow::ensure!(
                from == NodeId::Coordinator,
                "aggregate request from non-coordinator {from}"
            );
            if !cs.iters.contains_key(&iter) {
                let st = cs.take_iter_state();
                cs.iters.insert(iter, st);
            }
            let st = cs.iters.get_mut(&iter).unwrap();
            st.pending_request = Some(expected);
            maybe_respond(cfg, ep, session, cs, iter)?;
        }
        other => anyhow::bail!("center {} got unexpected {}", cfg.center_id, other.kind()),
    }
    Ok(())
}

/// If an aggregate request is pending and all submissions arrived,
/// reply with this center's share of the global sums and recycle the
/// iteration's state into the session pool. Response-assembly time
/// lands on the busy counter BEFORE the send, so the driver's
/// completion-time metrics read observes it.
fn maybe_respond(
    cfg: &CenterWorkerConfig,
    ep: &Endpoint,
    session: SessionId,
    cs: &mut CenterSession,
    iter: u32,
) -> anyhow::Result<()> {
    let (packed_h, full) = (cs.packed_h, cs.full_security);
    let Some(st) = cs.iters.get_mut(&iter) else {
        return Ok(());
    };
    let Some(expected) = st.pending_request else {
        return Ok(());
    };
    if st.acc.count < expected as usize {
        return Ok(());
    }
    let t = std::time::Instant::now();
    let hessian = if cs.screen || st.dp {
        // Score screens ([U | b], q) and DP noise rounds ([η | 0])
        // carry no Hessian in any mode, lead center included.
        HessianPayload::Absent
    } else if full {
        HessianPayload::Shared(st.acc.h_shared.clone().unwrap())
    } else if cfg.center_id == 0 {
        // Pragmatic mode: only the lead center carries the plaintext H,
        // summed in institution-id order for bit-determinism. Every
        // expected institution must have contributed exactly one
        // plaintext Hessian — an Absent-to-the-lead or duplicate
        // submission would otherwise yield a silently wrong aggregate.
        let mut pending = std::mem::take(&mut st.h_plain_pending);
        anyhow::ensure!(
            pending.len() == expected as usize,
            "lead center got {} plaintext hessians for {} expected submissions",
            pending.len(),
            expected
        );
        pending.sort_by_key(|(j, _)| *j);
        anyhow::ensure!(
            pending.windows(2).all(|w| w[0].0 != w[1].0),
            "duplicate plaintext hessian submission"
        );
        let mut h_sum = vec![0.0; packed_h];
        for (_, h) in &pending {
            for (a, b) in h_sum.iter_mut().zip(h) {
                *a += b;
            }
        }
        HessianPayload::Plain(h_sum)
    } else {
        HessianPayload::Absent
    };
    let response = Message::AggregateResponse {
        iter,
        center: cfg.center_id,
        hessian,
        g_share: st.acc.g.clone(),
        dev_share: st.acc.dev,
    };
    cs.busy_ns
        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
    ep.send_session(NodeId::Coordinator, session, &response)?;
    // Answered: zero the state and return it to the session pool.
    if let Some(st) = cs.iters.remove(&iter) {
        cs.recycle_iter_state(st);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Fp;
    use crate::fixed::FixedCodec;
    use crate::linalg::Matrix;
    use crate::session::{SessionSpec, ShardData};
    use crate::shamir::ShamirParams;
    use crate::transport::Network;
    use crate::util::rng::ChaCha20Rng;

    /// A spec whose shard shapes define (s, d); data content is unused
    /// by centers.
    fn make_spec(session: SessionId, s: usize, d: usize, t: usize, w: usize, full: bool) -> Arc<SessionSpec> {
        let shards = (0..s)
            .map(|_| {
                Arc::new(ShardData {
                    x: Matrix::zeros(4, d),
                    y: vec![0.0; 4],
                })
            })
            .collect();
        Arc::new(SessionSpec::new(
            session,
            shards,
            ShamirParams::new(t, w).unwrap(),
            FixedCodec::default(),
            full,
            1,
            crate::simd::Isa::Scalar,
            7,
        ))
    }

    fn registry_with(specs: Vec<Arc<SessionSpec>>) -> Arc<SessionRegistry> {
        let reg = SessionRegistry::new();
        for s in specs {
            reg.insert(s);
        }
        reg
    }

    /// Drive one center thread through a full aggregate round.
    #[test]
    fn center_aggregates_and_responds() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst0 = net.register(NodeId::Institution(0));
        let inst1 = net.register(NodeId::Institution(1));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(1, 2, 2, 1, 1, false)]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());

        let params = ShamirParams::new(1, 1).unwrap(); // single-holder degenerate scheme
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        // Two institutions submit g=[1,2] dev=3 h=[1,1,1] and g=[4,5] dev=6 h=[2,2,2].
        for (j, (g, dev, h)) in [
            (vec![1.0, 2.0], 3.0, vec![1.0, 1.0, 1.0]),
            (vec![4.0, 5.0], 6.0, vec![2.0, 2.0, 2.0]),
        ]
        .into_iter()
        .enumerate()
        {
            let shared =
                crate::secure::share_local_stats(params, &codec, &g, dev, &h, false, &mut rng)
                    .unwrap();
            let ep = if j == 0 { &inst0 } else { &inst1 };
            ep.send_session(
                NodeId::Center(0),
                1,
                &Message::ShareSubmission {
                    iter: 0,
                    institution: j as u16,
                    hessian: HessianPayload::Plain(h),
                    g_share: shared.g.per_holder[0].clone(),
                    dev_share: shared.dev.per_holder[0][0],
                },
            )
            .unwrap();
        }
        coord
            .send_session(NodeId::Center(0), 1, &Message::AggregateRequest { iter: 0, expected: 2 })
            .unwrap();
        let (_, session, resp) = coord.recv_session().unwrap();
        assert_eq!(session, 1);
        match resp {
            Message::AggregateResponse {
                iter,
                center,
                hessian,
                g_share,
                dev_share,
            } => {
                assert_eq!(iter, 0);
                assert_eq!(center, 0);
                // t=1: shares are the secrets themselves.
                let g = codec.decode_slice(&g_share);
                assert!((g[0] - 5.0).abs() < 1e-4 && (g[1] - 7.0).abs() < 1e-4);
                assert!((codec.decode(dev_share) - 9.0).abs() < 1e-4);
                match hessian {
                    HessianPayload::Plain(h) => {
                        assert_eq!(h, vec![3.0, 3.0, 3.0]);
                    }
                    _ => panic!("expected plain hessian"),
                }
            }
            other => panic!("unexpected {}", other.kind()),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// A screen session's lead center must answer with an ABSENT
    /// Hessian in pragmatic mode (no plaintext Hessians ever arrive on
    /// the screen path) and size its accumulator at stat_len = d+1.
    #[test]
    fn screen_session_lead_center_responds_absent() {
        let panel = Arc::new(crate::data::synthetic_panel("t", 24, 3, 2, 4, 1, 1.0, 3));
        let ds = &panel.covariates;
        let fit = crate::model::damped_newton_fit(&ds.x, &ds.y, 1e-3, 1e-10, 50, 20).unwrap();
        let stats = crate::model::local_stats(&ds.x, &ds.y, &fit.beta);
        let null = Arc::new(
            crate::model::NullModelCache::new(fit.beta.clone(), &stats.h, 1e-3).unwrap(),
        );
        let mut spec = SessionSpec::new(
            5,
            panel.shard_data().to_vec(),
            ShamirParams::new(1, 1).unwrap(),
            FixedCodec::default(),
            false,
            1,
            crate::simd::Isa::Scalar,
            7,
        );
        spec.screen = Some(Arc::new(crate::session::ScreenTask { panel, null, snp: 1 }));
        assert_eq!(spec.stat_len(), 4);
        let registry = registry_with(vec![Arc::new(spec)]);
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst0 = net.register(NodeId::Institution(0));
        let inst1 = net.register(NodeId::Institution(1));
        let cep = net.register(NodeId::Center(0));
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        let codec = FixedCodec::default();
        // [U | b] (4 elements) + q, Absent hessian, from both institutions.
        for (j, ep) in [(0u16, &inst0), (1, &inst1)] {
            let enc: Vec<Fp> =
                [1.0, 2.0, 3.0, 4.0].iter().map(|v| codec.encode(*v).unwrap()).collect();
            ep.send_session(
                NodeId::Center(0),
                5,
                &Message::ShareSubmission {
                    iter: 0,
                    institution: j,
                    hessian: HessianPayload::Absent,
                    g_share: enc,
                    dev_share: codec.encode(0.5).unwrap(),
                },
            )
            .unwrap();
        }
        coord
            .send_session(NodeId::Center(0), 5, &Message::AggregateRequest { iter: 0, expected: 2 })
            .unwrap();
        let (_, session, resp) = coord.recv_session().unwrap();
        assert_eq!(session, 5);
        match resp {
            Message::AggregateResponse { hessian, g_share, dev_share, .. } => {
                assert!(matches!(hessian, HessianPayload::Absent), "lead center, screen: Absent");
                assert_eq!(g_share.len(), 4);
                let g = codec.decode_slice(&g_share);
                for (got, want) in g.iter().zip(&[2.0, 4.0, 6.0, 8.0]) {
                    assert!((got - want).abs() < 1e-4);
                }
                assert!((codec.decode(dev_share) - 1.0).abs() < 1e-4);
            }
            other => panic!("unexpected {}", other.kind()),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Aggregate request arriving BEFORE all submissions must wait.
    #[test]
    fn request_before_submissions_waits() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(1));
        let registry = registry_with(vec![make_spec(3, 1, 1, 1, 2, false)]);
        let cfg = CenterWorkerConfig { center_id: 1, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        coord
            .send_session(NodeId::Center(1), 3, &Message::AggregateRequest { iter: 0, expected: 1 })
            .unwrap();
        // No response yet.
        assert!(coord
            .recv_timeout(std::time::Duration::from_millis(50))
            .unwrap()
            .is_none());
        inst.send_session(
            NodeId::Center(1),
            3,
            &Message::ShareSubmission {
                iter: 0,
                institution: 0,
                hessian: HessianPayload::Absent,
                g_share: vec![Fp::new(1)],
                dev_share: Fp::new(2),
            },
        )
        .unwrap();
        let (_, _, resp) = coord.recv_session().unwrap();
        assert!(matches!(resp, Message::AggregateResponse { .. }));
        coord.send(NodeId::Center(1), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Submissions for different iterations don't bleed into each other.
    #[test]
    fn iterations_are_isolated() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(2, 1, 1, 1, 1, false)]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        for (iter, v) in [(0u32, 10.0f64), (1, 20.0)] {
            inst.send_session(
                NodeId::Center(0),
                2,
                &Message::ShareSubmission {
                    iter,
                    institution: 0,
                    hessian: HessianPayload::Plain(vec![v]),
                    g_share: vec![Fp::new(1)],
                    dev_share: Fp::new(1),
                },
            )
            .unwrap();
        }
        coord
            .send_session(NodeId::Center(0), 2, &Message::AggregateRequest { iter: 1, expected: 1 })
            .unwrap();
        let (_, _, resp) = coord.recv_session().unwrap();
        match resp {
            Message::AggregateResponse { iter, hessian, .. } => {
                assert_eq!(iter, 1);
                assert_eq!(hessian, HessianPayload::Plain(vec![20.0]));
            }
            _ => panic!(),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Recycled iteration states carry no residue: consecutive rounds
    /// through one session (which reuse the pooled accumulator) must
    /// aggregate exactly as fresh states would.
    #[test]
    fn recycled_iteration_state_is_clean() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(6, 1, 2, 1, 1, false)]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        for (iter, (gv, h)) in [(10.0f64, 100.0f64), (20.0, 200.0), (30.0, 300.0)]
            .into_iter()
            .enumerate()
        {
            let iter = iter as u32;
            inst.send_session(
                NodeId::Center(0),
                6,
                &Message::ShareSubmission {
                    iter,
                    institution: 0,
                    hessian: HessianPayload::Plain(vec![h, h, h]),
                    g_share: vec![Fp::new(gv as u64), Fp::new(gv as u64 + 1)],
                    dev_share: Fp::new(7),
                },
            )
            .unwrap();
            coord
                .send_session(
                    NodeId::Center(0),
                    6,
                    &Message::AggregateRequest { iter, expected: 1 },
                )
                .unwrap();
            let (_, _, resp) = coord.recv_session().unwrap();
            match resp {
                Message::AggregateResponse { iter: ri, hessian, g_share, dev_share, .. } => {
                    assert_eq!(ri, iter);
                    assert_eq!(hessian, HessianPayload::Plain(vec![h, h, h]));
                    assert_eq!(g_share, vec![Fp::new(gv as u64), Fp::new(gv as u64 + 1)]);
                    assert_eq!(dev_share, Fp::new(7));
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Concurrent sessions aggregate independently on one center, and
    /// the plaintext Hessian folds in institution order regardless of
    /// arrival order.
    #[test]
    fn sessions_are_isolated_and_plain_fold_is_ordered() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let insts: Vec<_> = (0..3).map(|j| net.register(NodeId::Institution(j))).collect();
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![
            make_spec(10, 3, 1, 1, 1, false),
            make_spec(11, 3, 1, 1, 1, false),
        ]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        // Values where summation ORDER moves the f64 result: with three
        // addends, (1 + 1) + 1e16 = 1e16 + 2, but the institution-id
        // order (1e16 + 1) + 1 = 1e16 (each +1 rounds away). Submit in
        // arrival order 1, 2, 0 — the ordered fold must still produce
        // the institution-order sum, identically in both sessions.
        let vals = [1.0e16, 1.0, 1.0]; // per institution id
        let ordered_sum = (vals[0] + vals[1]) + vals[2]; // = 1e16
        let arrival_sum = (vals[1] + vals[2]) + vals[0]; // = 1e16 + 2
        assert_ne!(ordered_sum, arrival_sum, "values must expose ordering");
        for session in [10u32, 11] {
            for j in [1u16, 2, 0] {
                insts[j as usize]
                    .send_session(
                        NodeId::Center(0),
                        session,
                        &Message::ShareSubmission {
                            iter: 0,
                            institution: j,
                            hessian: HessianPayload::Plain(vec![vals[j as usize]]),
                            g_share: vec![Fp::new((j + 1) as u64 * session as u64)],
                            dev_share: Fp::new(1),
                        },
                    )
                    .unwrap();
            }
        }
        for session in [10u32, 11] {
            coord
                .send_session(
                    NodeId::Center(0),
                    session,
                    &Message::AggregateRequest { iter: 0, expected: 3 },
                )
                .unwrap();
        }
        let mut seen = HashMap::new();
        for _ in 0..2 {
            let (_, session, resp) = coord.recv_session().unwrap();
            match resp {
                Message::AggregateResponse { hessian, g_share, .. } => {
                    seen.insert(session, (hessian, g_share));
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        for session in [10u32, 11] {
            let (h, g) = &seen[&session];
            assert_eq!(
                *h,
                HessianPayload::Plain(vec![ordered_sum]),
                "session {session}: fold must follow institution order"
            );
            // g folded per session: (1 + 2 + 3)·session in the field.
            assert_eq!(g[0], Fp::new(6 * session as u64));
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// A duplicated submission frame must not double-count: the
    /// aggregate over {inst0, inst0-duplicate, inst1} equals the clean
    /// two-institution aggregate.
    #[test]
    fn duplicate_submission_is_idempotent() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst0 = net.register(NodeId::Institution(0));
        let inst1 = net.register(NodeId::Institution(1));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(4, 2, 1, 1, 1, false)]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        let submit = |ep: &crate::transport::Endpoint, j: u16, g: u64, h: f64| {
            ep.send_session(
                NodeId::Center(0),
                4,
                &Message::ShareSubmission {
                    iter: 0,
                    institution: j,
                    hessian: HessianPayload::Plain(vec![h]),
                    g_share: vec![Fp::new(g)],
                    dev_share: Fp::new(g),
                },
            )
            .unwrap();
        };
        submit(&inst0, 0, 5, 10.0);
        submit(&inst0, 0, 5, 10.0); // duplicated frame, bit-identical
        submit(&inst1, 1, 7, 20.0);
        coord
            .send_session(NodeId::Center(0), 4, &Message::AggregateRequest { iter: 0, expected: 2 })
            .unwrap();
        let (_, _, resp) = coord.recv_session().unwrap();
        match resp {
            Message::AggregateResponse { hessian, g_share, dev_share, .. } => {
                assert_eq!(hessian, HessianPayload::Plain(vec![30.0]));
                assert_eq!(g_share, vec![Fp::new(12)]);
                assert_eq!(dev_share, Fp::new(12));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// `SessionReopen` wipes the interrupted round's partial state so a
    /// replay starts clean; unknown sessions are a silent no-op.
    #[test]
    fn session_reopen_clears_partial_state() {
        use std::sync::atomic::AtomicUsize;
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(8, 2, 1, 1, 1, false)]);
        let gauge = Arc::new(AtomicUsize::new(0));
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: gauge.clone() };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        // A partial fold from the interrupted attempt...
        inst.send_session(
            NodeId::Center(0),
            8,
            &Message::ShareSubmission {
                iter: 0,
                institution: 0,
                hessian: HessianPayload::Plain(vec![999.0]),
                g_share: vec![Fp::new(999)],
                dev_share: Fp::new(999),
            },
        )
        .unwrap();
        // ...is wiped by the reopen (idempotent for session 77 which
        // was never opened)...
        coord
            .send_session(NodeId::Center(0), 8, &Message::SessionReopen { iter: 0 })
            .unwrap();
        coord
            .send_session(NodeId::Center(0), 77, &Message::SessionReopen { iter: 0 })
            .unwrap();
        // ...so the replayed round aggregates only its own frames.
        for (j, g) in [(0u16, 5u64), (1, 7)] {
            inst.send_session(
                NodeId::Center(0),
                8,
                &Message::ShareSubmission {
                    iter: 0,
                    institution: j,
                    hessian: HessianPayload::Plain(vec![g as f64]),
                    g_share: vec![Fp::new(g)],
                    dev_share: Fp::new(g),
                },
            )
            .unwrap();
        }
        coord
            .send_session(NodeId::Center(0), 8, &Message::AggregateRequest { iter: 0, expected: 2 })
            .unwrap();
        let (_, _, resp) = coord.recv_session().unwrap();
        match resp {
            Message::AggregateResponse { hessian, g_share, .. } => {
                assert_eq!(hessian, HessianPayload::Plain(vec![12.0]));
                assert_eq!(g_share, vec![Fp::new(12)]);
            }
            other => panic!("unexpected {}", other.kind()),
        }
        assert_eq!(gauge.load(Ordering::Relaxed), 1, "reopened session is live again");
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// A DP noise round folds `DpNoiseSubmission` shares exactly like
    /// gradient shares, dedups duplicated frames, and answers with an
    /// ABSENT Hessian even from the pragmatic lead center (whose
    /// plaintext-count check would otherwise reject the round).
    #[test]
    fn dp_noise_round_folds_and_responds_absent() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst0 = net.register(NodeId::Institution(0));
        let inst1 = net.register(NodeId::Institution(1));
        let cep = net.register(NodeId::Center(0));
        let mut spec = make_spec(12, 2, 2, 1, 1, false);
        Arc::get_mut(&mut spec).unwrap().dp = Some(crate::dp::DpParams {
            mechanism: crate::dp::DpMechanism::Gaussian,
            epsilon: 1.0,
            delta: 1e-6,
            sensitivity: 2.0,
            num_partials: 2,
            num_honest: 2,
            rows: 8,
        });
        let registry = registry_with(vec![spec]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        let submit = |ep: &crate::transport::Endpoint, j: u16, a: u64, b: u64| {
            ep.send_session(
                NodeId::Center(0),
                12,
                &Message::DpNoiseSubmission {
                    iter: 3,
                    institution: j,
                    noise_share: vec![Fp::new(a), Fp::new(b)],
                    mask_share: Fp::new(a + b),
                },
            )
            .unwrap();
        };
        submit(&inst0, 0, 5, 6);
        submit(&inst0, 0, 5, 6); // duplicated frame, bit-identical → dropped
        submit(&inst1, 1, 7, 8);
        coord
            .send_session(NodeId::Center(0), 12, &Message::AggregateRequest { iter: 3, expected: 2 })
            .unwrap();
        let (_, session, resp) = coord.recv_session().unwrap();
        assert_eq!(session, 12);
        match resp {
            Message::AggregateResponse { iter, hessian, g_share, dev_share, .. } => {
                assert_eq!(iter, 3);
                assert!(matches!(hessian, HessianPayload::Absent), "dp round: Absent everywhere");
                assert_eq!(g_share, vec![Fp::new(12), Fp::new(14)]);
                assert_eq!(dev_share, Fp::new(26));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        // The recycled state must not leak the dp flag into a Newton
        // round: a plain-Hessian iteration through the same session
        // pool still answers with the plaintext sum.
        inst0
            .send_session(
                NodeId::Center(0),
                12,
                &Message::ShareSubmission {
                    iter: 4,
                    institution: 0,
                    hessian: HessianPayload::Plain(vec![9.0, 9.0, 9.0]),
                    g_share: vec![Fp::new(1), Fp::new(2)],
                    dev_share: Fp::new(3),
                },
            )
            .unwrap();
        inst1
            .send_session(
                NodeId::Center(0),
                12,
                &Message::ShareSubmission {
                    iter: 4,
                    institution: 1,
                    hessian: HessianPayload::Plain(vec![1.0, 1.0, 1.0]),
                    g_share: vec![Fp::new(1), Fp::new(2)],
                    dev_share: Fp::new(3),
                },
            )
            .unwrap();
        coord
            .send_session(NodeId::Center(0), 12, &Message::AggregateRequest { iter: 4, expected: 2 })
            .unwrap();
        let (_, _, resp) = coord.recv_session().unwrap();
        match resp {
            Message::AggregateResponse { hessian, .. } => {
                assert_eq!(hessian, HessianPayload::Plain(vec![10.0, 10.0, 10.0]));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Malformed submissions abort the session (NodeError), not the
    /// worker.
    #[test]
    fn malformed_submission_reports_node_error() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![make_spec(5, 1, 4, 1, 1, false)]);
        let cfg = CenterWorkerConfig { center_id: 0, registry, live_sessions: Arc::new(AtomicUsize::new(0)) };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        // gradient share has d=2, session expects d=4
        inst.send_session(
            NodeId::Center(0),
            5,
            &Message::ShareSubmission {
                iter: 0,
                institution: 0,
                hessian: HessianPayload::Plain(vec![0.0; 10]),
                g_share: vec![Fp::ZERO; 2],
                dev_share: Fp::ZERO,
            },
        )
        .unwrap();
        let (_, session, msg) = coord.recv_session().unwrap();
        assert_eq!(session, 5);
        assert!(matches!(msg, Message::NodeError { node: 0, is_center: true, .. }));
        // Unknown session likewise.
        inst.send_session(
            NodeId::Center(0),
            99,
            &Message::ShareSubmission {
                iter: 0,
                institution: 0,
                hessian: HessianPayload::Absent,
                g_share: vec![],
                dev_share: Fp::ZERO,
            },
        )
        .unwrap();
        let (_, session, msg) = coord.recv_session().unwrap();
        assert_eq!(session, 99);
        assert!(matches!(msg, Message::NodeError { .. }));
        // Worker still alive.
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// `SessionClose`/`Abort` free per-session state (gauge-visible)
    /// and are acked even for sessions the center never opened.
    #[test]
    fn close_and_abort_free_state_and_always_ack() {
        use std::sync::atomic::AtomicUsize;
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(0));
        let registry = registry_with(vec![
            make_spec(1, 1, 2, 1, 1, false),
            make_spec(2, 1, 2, 1, 1, false),
        ]);
        let gauge = Arc::new(AtomicUsize::new(0));
        let cfg = CenterWorkerConfig {
            center_id: 0,
            registry,
            live_sessions: gauge.clone(),
        };
        let th = std::thread::spawn(move || run_center_worker(cfg, cep).unwrap());
        // Open both sessions with one submission each.
        for session in [1u32, 2] {
            inst.send_session(
                NodeId::Center(0),
                session,
                &Message::ShareSubmission {
                    iter: 0,
                    institution: 0,
                    hessian: HessianPayload::Plain(vec![0.0; 3]),
                    g_share: vec![Fp::new(1), Fp::new(2)],
                    dev_share: Fp::new(3),
                },
            )
            .unwrap();
        }
        // Close session 1, abort session 2: state drops before each ack.
        coord
            .send_session(NodeId::Center(0), 1, &Message::SessionClose { iter: 0, beta: vec![] })
            .unwrap();
        let (_, session, msg) = coord.recv_session().unwrap();
        assert_eq!(session, 1);
        assert_eq!(msg, Message::CloseAck { node: 0, is_center: true });
        assert_eq!(gauge.load(Ordering::Relaxed), 1);
        coord
            .send_session(
                NodeId::Center(0),
                2,
                &Message::Abort { reason: "test abort".to_string() },
            )
            .unwrap();
        let (_, session, msg) = coord.recv_session().unwrap();
        assert_eq!(session, 2);
        assert_eq!(msg, Message::CloseAck { node: 0, is_center: true });
        assert_eq!(gauge.load(Ordering::Relaxed), 0, "all state freed");
        // A close for a session this center never opened still acks.
        coord
            .send_session(NodeId::Center(0), 77, &Message::SessionClose { iter: 0, beta: vec![] })
            .unwrap();
        let (_, session, msg) = coord.recv_session().unwrap();
        assert_eq!(session, 77);
        assert!(matches!(msg, Message::CloseAck { .. }));
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }
}
