//! Computation-center node.
//!
//! A center is one of the w independent share holders. Per iteration
//! it folds each institution's submission into a streaming
//! [`SecureAccumulator`] (secure addition — Algorithm 2), and when the
//! coordinator requests the aggregate after all S institutions have
//! submitted, it answers with its share of the GLOBAL sums. It never
//! holds, sees, or transmits a reconstructable view of any single
//! institution's summaries — that is the whole point of the scheme,
//! and `attack::below_threshold_views_are_uniform` verifies it.

use crate::protocol::{HessianPayload, Message, NodeId};
use crate::secure::SecureAccumulator;
use crate::transport::Endpoint;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Static parameters a center needs.
#[derive(Clone, Debug)]
pub struct CenterConfig {
    pub center_id: u16,
    /// Model dimension d.
    pub d: usize,
    /// Packed Hessian length d(d+1)/2.
    pub packed_h: usize,
    /// Full-security mode (Hessian also arrives as shares).
    pub full_security: bool,
    /// Out-of-band telemetry: nanoseconds this center spent doing
    /// secure-aggregation work (folds + response assembly). Feeds the
    /// paper's "central runtime" metric; not part of the protocol.
    pub busy_ns: Arc<AtomicU64>,
}

impl CenterConfig {
    pub fn new(center_id: u16, d: usize, full_security: bool) -> Self {
        Self {
            center_id,
            d,
            packed_h: d * (d + 1) / 2,
            full_security,
            busy_ns: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Per-iteration center state.
struct IterState {
    acc: SecureAccumulator,
    /// Pending aggregate request: expected submission count.
    pending_request: Option<u16>,
}

/// Run the center event loop until `Shutdown`.
///
/// Owns its endpoint; spawn on a dedicated thread. Fatal errors are
/// reported to the coordinator before returning.
pub fn run_center(cfg: CenterConfig, ep: Endpoint) -> anyhow::Result<()> {
    let id = cfg.center_id;
    match run_center_inner(cfg, &ep) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = ep.send(
                NodeId::Coordinator,
                &Message::NodeError {
                    node: id,
                    is_center: true,
                    error: format!("{e:#}"),
                },
            );
            Err(e)
        }
    }
}

fn run_center_inner(cfg: CenterConfig, ep: &Endpoint) -> anyhow::Result<()> {
    let mut iters: HashMap<u32, IterState> = HashMap::new();
    loop {
        let (from, msg) = ep.recv()?;
        match msg {
            Message::ShareSubmission {
                iter,
                institution: _,
                hessian,
                g_share,
                dev_share,
            } => {
                anyhow::ensure!(
                    matches!(from, NodeId::Institution(_)),
                    "submission from non-institution {from}"
                );
                let st = iters.entry(iter).or_insert_with(|| IterState {
                    acc: SecureAccumulator::new(cfg.d, cfg.packed_h, cfg.full_security),
                    pending_request: None,
                });
                let t = std::time::Instant::now();
                st.acc.fold(&g_share, dev_share, &hessian)?;
                maybe_respond(&cfg, &ep, iter, st)?;
                cfg.busy_ns
                    .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if iters
                    .get(&iter)
                    .map(|s| s.pending_request.is_none() && s.acc.count == 0)
                    .unwrap_or(false)
                {
                    iters.remove(&iter);
                }
            }
            Message::AggregateRequest { iter, expected } => {
                anyhow::ensure!(
                    from == NodeId::Coordinator,
                    "aggregate request from non-coordinator {from}"
                );
                let st = iters.entry(iter).or_insert_with(|| IterState {
                    acc: SecureAccumulator::new(cfg.d, cfg.packed_h, cfg.full_security),
                    pending_request: None,
                });
                st.pending_request = Some(expected);
                let t = std::time::Instant::now();
                maybe_respond(&cfg, &ep, iter, st)?;
                cfg.busy_ns
                    .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            Message::Finished { iter, .. } => {
                // Convergence: drop any state at or before this iteration.
                iters.retain(|&k, _| k > iter);
            }
            Message::Shutdown => return Ok(()),
            other => anyhow::bail!("center {} got unexpected {}", cfg.center_id, other.kind()),
        }
        // Garbage-collect answered iterations.
        iters.retain(|_, st| st.pending_request.is_some() || st.acc.count > 0);
    }
}

/// If an aggregate request is pending and all submissions arrived,
/// reply with this center's share of the global sums and clear state.
fn maybe_respond(
    cfg: &CenterConfig,
    ep: &&Endpoint,
    iter: u32,
    st: &mut IterState,
) -> anyhow::Result<()> {
    let Some(expected) = st.pending_request else {
        return Ok(());
    };
    if st.acc.count < expected as usize {
        return Ok(());
    }
    let hessian = if cfg.full_security {
        HessianPayload::Shared(st.acc.h_shared.clone().unwrap())
    } else if cfg.center_id == 0 {
        // Pragmatic mode: only the lead center carries the plaintext H.
        HessianPayload::Plain(st.acc.h_plain.clone().unwrap())
    } else {
        HessianPayload::Absent
    };
    ep.send(
        NodeId::Coordinator,
        &Message::AggregateResponse {
            iter,
            center: cfg.center_id,
            hessian,
            g_share: st.acc.g.clone(),
            dev_share: st.acc.dev,
        },
    )?;
    // Reset so the retain() in the loop drops this iteration.
    st.pending_request = None;
    st.acc = SecureAccumulator::new(cfg.d, cfg.packed_h, cfg.full_security);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Fp;
    use crate::fixed::FixedCodec;
    use crate::shamir::ShamirParams;
    use crate::transport::Network;
    use crate::util::rng::ChaCha20Rng;

    /// Drive one center thread through a full aggregate round.
    #[test]
    fn center_aggregates_and_responds() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst0 = net.register(NodeId::Institution(0));
        let inst1 = net.register(NodeId::Institution(1));
        let cep = net.register(NodeId::Center(0));
        let cfg = CenterConfig::new(0, 2, false);
        let th = std::thread::spawn(move || run_center(cfg, cep).unwrap());

        let params = ShamirParams::new(1, 1).unwrap(); // single-holder degenerate scheme
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        // Two institutions submit g=[1,2] dev=3 h=[1,1,1] and g=[4,5] dev=6 h=[2,2,2].
        for (j, (g, dev, h)) in [
            (vec![1.0, 2.0], 3.0, vec![1.0, 1.0, 1.0]),
            (vec![4.0, 5.0], 6.0, vec![2.0, 2.0, 2.0]),
        ]
        .into_iter()
        .enumerate()
        {
            let shared =
                crate::secure::share_local_stats(params, &codec, &g, dev, &h, false, &mut rng)
                    .unwrap();
            let ep = if j == 0 { &inst0 } else { &inst1 };
            ep.send(
                NodeId::Center(0),
                &Message::ShareSubmission {
                    iter: 0,
                    institution: j as u16,
                    hessian: HessianPayload::Plain(h),
                    g_share: shared.g.per_holder[0].clone(),
                    dev_share: shared.dev.per_holder[0][0],
                },
            )
            .unwrap();
        }
        coord
            .send(NodeId::Center(0), &Message::AggregateRequest { iter: 0, expected: 2 })
            .unwrap();
        let (_, resp) = coord.recv().unwrap();
        match resp {
            Message::AggregateResponse {
                iter,
                center,
                hessian,
                g_share,
                dev_share,
            } => {
                assert_eq!(iter, 0);
                assert_eq!(center, 0);
                // t=1: shares are the secrets themselves.
                let g = codec.decode_slice(&g_share);
                assert!((g[0] - 5.0).abs() < 1e-4 && (g[1] - 7.0).abs() < 1e-4);
                assert!((codec.decode(dev_share) - 9.0).abs() < 1e-4);
                match hessian {
                    HessianPayload::Plain(h) => {
                        assert_eq!(h, vec![3.0, 3.0, 3.0]);
                    }
                    _ => panic!("expected plain hessian"),
                }
            }
            other => panic!("unexpected {}", other.kind()),
        }
        coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Aggregate request arriving BEFORE all submissions must wait.
    #[test]
    fn request_before_submissions_waits() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        let cep = net.register(NodeId::Center(1));
        let cfg = CenterConfig::new(1, 1, false);
        let th = std::thread::spawn(move || run_center(cfg, cep).unwrap());
        coord
            .send(NodeId::Center(1), &Message::AggregateRequest { iter: 0, expected: 1 })
            .unwrap();
        // No response yet.
        assert!(coord
            .recv_timeout(std::time::Duration::from_millis(50))
            .unwrap()
            .is_none());
        inst.send(
            NodeId::Center(1),
            &Message::ShareSubmission {
                iter: 0,
                institution: 0,
                hessian: HessianPayload::Plain(vec![1.0]),
                g_share: vec![Fp::new(1)],
                dev_share: Fp::new(2),
            },
        )
        .unwrap();
        let (_, resp) = coord.recv().unwrap();
        assert!(matches!(resp, Message::AggregateResponse { .. }));
        coord.send(NodeId::Center(1), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }

    /// Submissions for different iterations don't bleed into each other.
    #[test]
    fn iterations_are_isolated() {
        let net = Network::new();
        let coord = net.register(NodeId::Coordinator);
        let inst = net.register(NodeId::Institution(0));
        // center 0 (the lead) so pragmatic-mode responses carry Plain H
        let cep = net.register(NodeId::Center(2));
        let cfg = CenterConfig::new(0, 1, false);
        let th = std::thread::spawn(move || run_center(cfg, cep).unwrap());
        for (iter, v) in [(0u32, 10.0f64), (1, 20.0)] {
            inst.send(
                NodeId::Center(2),
                &Message::ShareSubmission {
                    iter,
                    institution: 0,
                    hessian: HessianPayload::Plain(vec![v]),
                    g_share: vec![Fp::new(1)],
                    dev_share: Fp::new(1),
                },
            )
            .unwrap();
        }
        coord
            .send(NodeId::Center(2), &Message::AggregateRequest { iter: 1, expected: 1 })
            .unwrap();
        let (_, resp) = coord.recv().unwrap();
        match resp {
            Message::AggregateResponse { iter, hessian, .. } => {
                assert_eq!(iter, 1);
                assert_eq!(hessian, HessianPayload::Plain(vec![20.0]));
            }
            _ => panic!(),
        }
        coord.send(NodeId::Center(2), &Message::Shutdown).unwrap();
        th.join().unwrap();
    }
}
