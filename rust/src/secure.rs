//! Secure computation primitives over secret shares (the paper's
//! Algorithm 2 and the multiply-by-public-constant primitive).
//!
//! A computation center never sees plaintext summaries; it holds one
//! share per secret and computes on shares *locally*:
//!
//! * **secure addition** — a center adds its shares of A and B to get
//!   its share of A+B (polynomials add pointwise, the secret is the
//!   constant term);
//! * **secure multiply-by-public** — a center multiplies its share by
//!   a public field constant.
//!
//! [`SecureAccumulator`] is the per-center, per-iteration state that
//! folds institution submissions together as they arrive, so secure
//! aggregation is streaming (O(1) memory in the number of
//! institutions) — this is what makes Fig 4's flat central time hold.

use crate::field::{add_assign_slice, mul_scalar_slice, Fp};
use crate::fixed::{FixedCodec, FixedError};
use crate::shamir::{
    share_batch_with, ShamirParams, ShareBatch, VandermondeTable, SHARE_CHUNK,
};
use crate::util::rng::{derive_seed, ChaCha20Rng, Rng};

/// Secure addition: combine two share vectors held by the same center.
/// (Algorithm 2, one holder's step.)
#[inline]
pub fn secure_add(acc: &mut [Fp], incoming: &[Fp]) {
    add_assign_slice(acc, incoming);
}

/// Secure multiplication by a public constant, in place.
#[inline]
pub fn secure_mul_public(shares: &mut [Fp], c: Fp) {
    mul_scalar_slice(shares, c);
}

/// Per-center streaming aggregator for one Newton iteration.
///
/// Holds this center's running share of Σ_j g_j, Σ_j dev_j, and (in
/// full-security mode) Σ_j H_j; pragmatic mode accumulates the
/// plaintext Hessian sum instead.
#[derive(Clone, Debug)]
pub struct SecureAccumulator {
    /// Share of the aggregated gradient (d elements).
    pub g: Vec<Fp>,
    /// Share of the aggregated deviance.
    pub dev: Fp,
    /// Share of the aggregated packed Hessian (full mode), if any.
    pub h_shared: Option<Vec<Fp>>,
    /// Plaintext aggregated packed Hessian (pragmatic mode), if any.
    pub h_plain: Option<Vec<f64>>,
    /// Number of submissions folded in.
    pub count: usize,
}

impl SecureAccumulator {
    pub fn new(d: usize, packed_h: usize, full_security: bool) -> Self {
        Self {
            g: vec![Fp::ZERO; d],
            dev: Fp::ZERO,
            h_shared: full_security.then(|| vec![Fp::ZERO; packed_h]),
            h_plain: (!full_security).then(|| vec![0.0; packed_h]),
            count: 0,
        }
    }

    /// Zero the accumulator in place, keeping its mode and buffers — a
    /// center recycles accumulators across iterations instead of
    /// reallocating (`center::run_center_worker`'s iteration pool).
    pub fn reset(&mut self) {
        self.g.fill(Fp::ZERO);
        self.dev = Fp::ZERO;
        if let Some(h) = self.h_shared.as_mut() {
            h.fill(Fp::ZERO);
        }
        if let Some(h) = self.h_plain.as_mut() {
            h.fill(0.0);
        }
        self.count = 0;
    }

    /// Fold in one institution's submission (this center's slice of it).
    pub fn fold(
        &mut self,
        g_share: &[Fp],
        dev_share: Fp,
        hessian: &crate::protocol::HessianPayload,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            g_share.len() == self.g.len(),
            "gradient share length {} != {}",
            g_share.len(),
            self.g.len()
        );
        secure_add(&mut self.g, g_share);
        self.dev = self.dev + dev_share;
        match (hessian, self.h_shared.as_mut(), self.h_plain.as_mut()) {
            (crate::protocol::HessianPayload::Shared(hs), Some(acc), _) => {
                anyhow::ensure!(hs.len() == acc.len(), "hessian share length mismatch");
                secure_add(acc, hs);
            }
            (crate::protocol::HessianPayload::Plain(hp), _, Some(acc)) => {
                anyhow::ensure!(hp.len() == acc.len(), "hessian length mismatch");
                for (a, b) in acc.iter_mut().zip(hp) {
                    *a += b;
                }
            }
            // Pragmatic mode, non-lead center: nothing to fold for H.
            (crate::protocol::HessianPayload::Absent, _, Some(_)) => {}
            _ => anyhow::bail!("hessian payload mode does not match accumulator mode"),
        }
        self.count += 1;
        Ok(())
    }
}

/// Institution-side sharing of one iteration's local summaries.
///
/// Returns, for each center, the triple of payloads it should receive.
/// The share polynomials are drawn from `rng` (must be crypto-grade in
/// deployments; see `util::rng::ChaCha20Rng`).
pub struct SharedStats {
    /// Per-center gradient shares.
    pub g: ShareBatch,
    /// Per-center deviance shares.
    pub dev: ShareBatch,
    /// Per-center packed-Hessian shares (full mode only).
    pub h: Option<ShareBatch>,
}

/// Per-node sharing state hoisted out of the iteration loop: the
/// Shamir parameters plus the precomputed Vandermonde evaluation
/// powers, built once per `(t, w)` and reused for every batch the node
/// ever shares (institutions build one per run).
#[derive(Clone, Debug)]
pub struct ShareContext {
    table: VandermondeTable,
}

impl ShareContext {
    pub fn new(params: ShamirParams) -> Self {
        Self {
            table: VandermondeTable::new(params),
        }
    }

    pub fn params(&self) -> ShamirParams {
        self.table.params()
    }

    /// The cached Vandermonde evaluation table.
    pub fn table(&self) -> &VandermondeTable {
        &self.table
    }

    /// Share one batch through the cached table.
    pub fn share<R: Rng>(&self, secrets: &[Fp], rng: &mut R) -> ShareBatch {
        share_batch_with(&self.table, secrets, rng)
    }
}

/// Pooled buffers of the fused encode+share sweep, owned by the
/// engine's worker layer and reused for every batch any session ever
/// shares through it. All growth is monotone (`Vec` capacity never
/// shrinks), so after the first iteration at the largest dimension the
/// per-iteration pipeline allocates nothing: per-holder wire buffers,
/// per-thread chunk scratch, and the thread partition table all live
/// here.
#[derive(Default)]
pub struct SharePool {
    /// Per-holder wire share vectors; `per_holder[j][k]` is holder j's
    /// share of secret k for the most recent [`encode_share_into`].
    per_holder: Vec<Vec<Fp>>,
    /// Per-worker chunk scratch (encode + coefficient buffers).
    scratch: Vec<ChunkScratch>,
    /// Secret-count boundaries of the last thread partition.
    bounds: Vec<usize>,
}

/// One worker's chunk-local scratch: the encoded secrets, the random
/// coefficient matrix (coefficient-major), and an error slot carrying
/// a mid-sweep encode failure out of the fan-out.
#[derive(Default)]
struct ChunkScratch {
    enc: Vec<Fp>,
    coeffs: Vec<Fp>,
    err: Option<FixedError>,
}

impl SharePool {
    pub fn new() -> SharePool {
        SharePool::default()
    }

    /// Holder j's wire shares from the most recent sweep (`len` secrets).
    pub fn holder(&self, j: usize) -> &[Fp] {
        &self.per_holder[j]
    }

    /// Number of holder buffers currently materialized.
    pub fn num_holders(&self) -> usize {
        self.per_holder.len()
    }

    /// Grow (never shrink capacity) to serve a `(w, t, k)` sweep with
    /// `workers` chunk workers.
    fn ensure(&mut self, w: usize, t: usize, k: usize, workers: usize) {
        if self.per_holder.len() < w {
            self.per_holder.resize_with(w, Vec::new);
        }
        for h in self.per_holder.iter_mut().take(w) {
            h.resize(k, Fp::ZERO);
        }
        if self.scratch.len() < workers {
            self.scratch.resize_with(workers, ChunkScratch::default);
        }
        for sc in self.scratch.iter_mut().take(workers) {
            sc.enc.resize(SHARE_CHUNK, Fp::ZERO);
            sc.coeffs.resize((t - 1) * SHARE_CHUNK, Fp::ZERO);
            sc.err = None;
        }
    }
}

/// Prepare one secret chunk: encode `values` (the chunk's f64 slice)
/// into `sc.enc` and draw the chunk's coefficient matrix from its OWN
/// ChaCha20 stream (secret-major draw order, coefficient-major
/// storage, exactly like `share_batch_with` within the chunk). The
/// caller then evaluates every holder via
/// [`eval_shares_chunk`](crate::shamir::eval_shares_chunk).
fn prepare_chunk(
    t: usize,
    codec: &FixedCodec,
    values: &[f64],
    chunk_seed: u64,
    sc: &mut ChunkScratch,
) -> Result<(), FixedError> {
    let len = values.len();
    codec.encode_slice_into(values, &mut sc.enc[..len])?;
    let coeffs = &mut sc.coeffs[..(t - 1) * len];
    let mut rng = ChaCha20Rng::seed_from_u64(chunk_seed);
    for s in 0..len {
        for i in 0..t - 1 {
            coeffs[i * len + s] = Fp::random(&mut rng);
        }
    }
    Ok(())
}

/// The fused, threaded encode+share sweep: encode f64 summaries and
/// evaluate Shamir shares directly into `pool`'s per-holder wire
/// buffers — no intermediate `Vec<Fp>` and no per-iteration
/// allocation once the pool is warm.
///
/// The batch is cut into [`SHARE_CHUNK`]-secret chunks; each chunk's
/// polynomial coefficients come from an independent ChaCha20 stream
/// seeded with `derive_seed(seed, chunk index)`. `threads` workers
/// (0 = one per core) fan out over *contiguous chunk ranges*, so the
/// output is a pure function of `(values, seed, scheme)` — bit-
/// identical across thread counts — and any t-quorum reconstructs to
/// exactly the encodings that [`share_batch_with`] over
/// `FixedCodec::encode_slice` (the retained reference path) yields;
/// `tests/prop_secure_pipeline.rs` gates both properties.
///
/// Thread fan-out engages only when the batch spans several chunks;
/// the threaded path's only non-pooled cost is the `std::thread` scope
/// itself plus O(w·workers) slice headers — the d=85 packed-Hessian
/// sweep in single-thread mode is strictly allocation-free.
pub fn encode_share_into(
    ctx: &ShareContext,
    codec: &FixedCodec,
    values: &[f64],
    seed: u64,
    threads: usize,
    pool: &mut SharePool,
) -> anyhow::Result<()> {
    encode_share_into_isa(ctx, codec, values, seed, threads, crate::simd::Isa::Scalar, pool)
}

/// [`encode_share_into`] with explicit ISA dispatch for the
/// per-(chunk, holder) share evaluation
/// ([`crate::shamir::eval_shares_chunk_isa`]). Chunking, RNG streams
/// and thread fan-out are untouched, so the output remains a pure
/// function of `(values, seed, scheme)` — bit-identical across BOTH
/// thread counts and ISAs (the encode step and coefficient draw are
/// ISA-independent; the evaluation kernel is gated bit-identical).
pub fn encode_share_into_isa(
    ctx: &ShareContext,
    codec: &FixedCodec,
    values: &[f64],
    seed: u64,
    threads: usize,
    isa: crate::simd::Isa,
    pool: &mut SharePool,
) -> anyhow::Result<()> {
    let params = ctx.params();
    let (t, w) = (params.threshold, params.num_holders);
    let table = ctx.table();
    let k = values.len();
    let chunks = ((k + SHARE_CHUNK - 1) / SHARE_CHUNK).max(1);
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    };
    let workers = threads.min(chunks);
    pool.ensure(w, t, k, workers);
    let SharePool {
        per_holder,
        scratch,
        bounds,
    } = pool;

    if workers <= 1 {
        // Strictly allocation-free: chunk scratch and wire buffers come
        // from the pool, chunks write holder ranges directly.
        let sc = &mut scratch[0];
        let mut lo = 0;
        for c in 0..chunks {
            let hi = (lo + SHARE_CHUNK).min(k);
            if lo >= hi {
                break;
            }
            let len = hi - lo;
            prepare_chunk(t, codec, &values[lo..hi], derive_seed(seed, c as u64), sc)
                .map_err(anyhow::Error::new)?;
            for (j, h) in per_holder.iter_mut().take(w).enumerate() {
                crate::shamir::eval_shares_chunk_isa(
                    table.holder_powers(j),
                    &sc.enc[..len],
                    &sc.coeffs[..(t - 1) * len],
                    &mut h[lo..hi],
                    isa,
                );
            }
            lo = hi;
        }
        return Ok(());
    }

    // Contiguous chunk ranges per worker (whole chunks, near-equal);
    // per-chunk seeds make the result identical to the 1-worker path.
    let chunks_per = (chunks + workers - 1) / workers;
    bounds.clear();
    for p in 0..=workers {
        bounds.push(((p * chunks_per) * SHARE_CHUNK).min(k));
    }
    // Split every holder buffer at the partition bounds so each worker
    // owns disjoint slices of all w wire buffers. These views are the
    // fan-out's only non-pooled state: O(w·workers) slice headers.
    let mut views: Vec<Vec<&mut [Fp]>> = (0..workers).map(|_| Vec::with_capacity(w)).collect();
    for h in per_holder.iter_mut().take(w) {
        let mut rest: &mut [Fp] = &mut h[..k];
        for (p, view) in views.iter_mut().enumerate() {
            let take = bounds[p + 1] - bounds[p];
            let (head, tail) = rest.split_at_mut(take);
            view.push(head);
            rest = tail;
        }
    }
    std::thread::scope(|s| {
        for ((p, mut view), sc) in views.drain(..).enumerate().zip(scratch.iter_mut()) {
            let (lo, hi) = (bounds[p], bounds[p + 1]);
            if lo >= hi {
                continue;
            }
            let vals = &values[lo..hi];
            s.spawn(move || {
                let first_chunk = lo / SHARE_CHUNK;
                let mut off = 0;
                while off < vals.len() {
                    let len = SHARE_CHUNK.min(vals.len() - off);
                    let chunk_idx = first_chunk + off / SHARE_CHUNK;
                    if let Err(e) = prepare_chunk(
                        t,
                        codec,
                        &vals[off..off + len],
                        derive_seed(seed, chunk_idx as u64),
                        sc,
                    ) {
                        sc.err = Some(e);
                        return;
                    }
                    for (j, out) in view.iter_mut().enumerate() {
                        crate::shamir::eval_shares_chunk_isa(
                            table.holder_powers(j),
                            &sc.enc[..len],
                            &sc.coeffs[..(t - 1) * len],
                            &mut out[off..off + len],
                            isa,
                        );
                    }
                    off += len;
                }
            });
        }
    });
    for sc in scratch.iter().take(workers) {
        if let Some(e) = sc.err {
            return Err(anyhow::Error::new(e));
        }
    }
    Ok(())
}

/// Encode-and-share local statistics (reference/compat path).
///
/// `g_plain` is the local gradient (d), `dev_plain` the local deviance,
/// `h_packed_plain` the packed upper-triangular Hessian — shared only
/// when `full_security` is set (pragmatic mode sends it plaintext).
///
/// Convenience wrapper building a fresh [`ShareContext`]. The protocol
/// hot path no longer routes through here: institutions protect their
/// summaries with the fused pooled [`encode_share_into`] sweep; this
/// entry point (and [`share_local_stats_with`]) remains as the
/// eager-allocation reference the pipeline gates compare against.
pub fn share_local_stats<R: Rng>(
    params: ShamirParams,
    codec: &FixedCodec,
    g_plain: &[f64],
    dev_plain: f64,
    h_packed_plain: &[f64],
    full_security: bool,
    rng: &mut R,
) -> anyhow::Result<SharedStats> {
    share_local_stats_with(
        &ShareContext::new(params),
        codec,
        g_plain,
        dev_plain,
        h_packed_plain,
        full_security,
        rng,
    )
}

/// [`share_local_stats`] through a caller-owned [`ShareContext`] (the
/// allocation for the Vandermonde table happens once per run, not once
/// per iteration).
pub fn share_local_stats_with<R: Rng>(
    ctx: &ShareContext,
    codec: &FixedCodec,
    g_plain: &[f64],
    dev_plain: f64,
    h_packed_plain: &[f64],
    full_security: bool,
    rng: &mut R,
) -> anyhow::Result<SharedStats> {
    let g_enc = codec.encode_slice(g_plain)?;
    let dev_enc = codec.encode(dev_plain)?;
    let g = ctx.share(&g_enc, rng);
    let dev = ctx.share(&[dev_enc], rng);
    let h = if full_security {
        let h_enc = codec.encode_slice(h_packed_plain)?;
        Some(ctx.share(&h_enc, rng))
    } else {
        None
    };
    Ok(SharedStats { g, dev, h })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::HessianPayload;
    use crate::shamir::reconstruct_batch;
    use crate::util::rng::ChaCha20Rng;

    fn params() -> ShamirParams {
        ShamirParams::new(3, 5).unwrap()
    }

    #[test]
    fn streaming_aggregation_equals_plain_sum() {
        // 4 institutions' gradients, shared, folded per center, then the
        // reconstructed aggregate must equal the plaintext sum.
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        let d = 6;
        let grads: Vec<Vec<f64>> = (0..4)
            .map(|j| (0..d).map(|k| (j * d + k) as f64 * 0.25 - 2.0).collect())
            .collect();
        let devs = [10.5, 20.25, 30.0, 5.75];

        let mut accs: Vec<SecureAccumulator> =
            (0..5).map(|_| SecureAccumulator::new(d, 1, false)).collect();
        for (j, g) in grads.iter().enumerate() {
            let shared =
                share_local_stats(p, &codec, g, devs[j], &[1.0], false, &mut rng).unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                acc.fold(
                    &shared.g.per_holder[c],
                    shared.dev.per_holder[c][0],
                    &HessianPayload::Plain(vec![1.0]),
                )
                .unwrap();
            }
        }
        // Reconstruct from 3 of 5 centers.
        let quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
            .iter()
            .map(|&c| (c, accs[c].g.as_slice()))
            .collect();
        let g_total = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        let expect: Vec<f64> = (0..d)
            .map(|k| grads.iter().map(|g| g[k]).sum::<f64>())
            .collect();
        for (a, b) in g_total.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // deviance
        let dev_quorum: Vec<(usize, Fp)> = [1usize, 2, 3]
            .iter()
            .map(|&c| (c, accs[c].dev))
            .collect();
        let dev_total =
            codec.decode(crate::shamir::reconstruct_scalar(p, &dev_quorum).unwrap());
        assert!((dev_total - devs.iter().sum::<f64>()).abs() < 1e-4);
        // plaintext hessian accumulated 4×
        assert!((accs[0].h_plain.as_ref().unwrap()[0] - 4.0).abs() < 1e-12);
        assert_eq!(accs[0].count, 4);
    }

    #[test]
    fn full_mode_shares_hessian_too() {
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(2);
        let h1 = [1.0, 2.0, 3.0];
        let h2 = [0.5, -1.0, 4.0];
        let mut accs: Vec<SecureAccumulator> =
            (0..5).map(|_| SecureAccumulator::new(2, 3, true)).collect();
        for h in [&h1[..], &h2[..]] {
            let shared = share_local_stats(p, &codec, &[0.0, 0.0], 0.0, h, true, &mut rng).unwrap();
            let hs = shared.h.unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                acc.fold(
                    &shared.g.per_holder[c],
                    shared.dev.per_holder[c][0],
                    &HessianPayload::Shared(hs.per_holder[c].clone()),
                )
                .unwrap();
            }
        }
        let quorum: Vec<(usize, &[Fp])> = [0usize, 1, 2]
            .iter()
            .map(|&c| (c, accs[c].h_shared.as_ref().unwrap().as_slice()))
            .collect();
        let h_total = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        for (k, expect) in [1.5, 1.0, 7.0].iter().enumerate() {
            assert!((h_total[k] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn mode_mismatch_is_rejected() {
        let mut acc = SecureAccumulator::new(2, 3, false); // pragmatic
        let err = acc.fold(
            &[Fp::ZERO, Fp::ZERO],
            Fp::ZERO,
            &HessianPayload::Shared(vec![Fp::ZERO; 3]),
        );
        assert!(err.is_err());
        let mut acc = SecureAccumulator::new(2, 3, true); // full
        let err = acc.fold(
            &[Fp::ZERO, Fp::ZERO],
            Fp::ZERO,
            &HessianPayload::Plain(vec![0.0; 3]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn length_mismatch_is_rejected() {
        let mut acc = SecureAccumulator::new(4, 1, false);
        assert!(acc
            .fold(&[Fp::ZERO; 3], Fp::ZERO, &HessianPayload::Plain(vec![0.0]))
            .is_err());
        assert!(acc
            .fold(
                &[Fp::ZERO; 4],
                Fp::ZERO,
                &HessianPayload::Plain(vec![0.0, 1.0])
            )
            .is_err());
    }

    #[test]
    fn fused_sweep_is_thread_count_invariant_and_reconstructs() {
        // Chunk-forked RNG streams make the fused sweep a pure function
        // of (values, seed, scheme): per-holder wire buffers must be
        // bitwise identical across worker counts, and any t-quorum must
        // reconstruct to exactly the encodings the reference path
        // (encode_slice + share_batch_with) reconstructs to.
        let p = params();
        let ctx = ShareContext::new(p);
        let codec = FixedCodec::default();
        let k = crate::shamir::SHARE_CHUNK * 2 + 17; // straddles chunks
        let mut rng = crate::util::rng::SplitMix64::new(3);
        let values: Vec<f64> = (0..k)
            .map(|_| rng.next_range_f64(-1e4, 1e4))
            .collect();
        let mut pools: Vec<SharePool> = (0..3).map(|_| SharePool::new()).collect();
        for (threads, pool) in [1usize, 2, 4].iter().zip(pools.iter_mut()) {
            encode_share_into(&ctx, &codec, &values, 0xFEED, *threads, pool).unwrap();
        }
        for j in 0..5 {
            assert_eq!(pools[0].holder(j), pools[1].holder(j), "holder {j} 1v2");
            assert_eq!(pools[0].holder(j), pools[2].holder(j), "holder {j} 1v4");
        }
        // reconstruction equivalence vs the retained reference path
        let enc = codec.encode_slice(&values).unwrap();
        let mut rrng = ChaCha20Rng::seed_from_u64(9);
        let reference = ctx.share(&enc, &mut rrng);
        let ref_quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
            .iter()
            .map(|&j| (j, reference.per_holder[j].as_slice()))
            .collect();
        let fused_quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
            .iter()
            .map(|&j| (j, pools[0].holder(j)))
            .collect();
        let from_ref = reconstruct_batch(p, &ref_quorum).unwrap();
        let from_fused = reconstruct_batch(p, &fused_quorum).unwrap();
        assert_eq!(from_fused, enc);
        assert_eq!(from_fused, from_ref);
    }

    #[test]
    fn fused_sweep_reuses_pool_across_batch_sizes() {
        // One pool serves batches of different lengths (a session's g,
        // dev, and packed-H sweeps interleave): each call's holder
        // buffers carry exactly the current batch.
        let ctx = ShareContext::new(params());
        let codec = FixedCodec::default();
        let mut pool = SharePool::new();
        for k in [3655usize, 1, 86, 3655] {
            let values: Vec<f64> = (0..k).map(|i| i as f64 * 0.5 - 10.0).collect();
            encode_share_into(&ctx, &codec, &values, k as u64, 2, &mut pool).unwrap();
            assert_eq!(pool.holder(0).len(), k);
            let quorum: Vec<(usize, &[Fp])> =
                (0..3).map(|j| (j, pool.holder(j))).collect();
            let rec = reconstruct_batch(ctx.params(), &quorum).unwrap();
            assert_eq!(rec, codec.encode_slice(&values).unwrap(), "k={k}");
        }
        // degenerate empty batch
        encode_share_into(&ctx, &codec, &[], 7, 4, &mut pool).unwrap();
        assert_eq!(pool.holder(0).len(), 0);
    }

    #[test]
    fn fused_sweep_propagates_encode_errors() {
        let ctx = ShareContext::new(params());
        let codec = FixedCodec::default();
        let mut pool = SharePool::new();
        // single-threaded path
        assert!(encode_share_into(&ctx, &codec, &[f64::NAN], 1, 1, &mut pool).is_err());
        // threaded path: bad value in the LAST chunk of several
        let mut values = vec![0.5; crate::shamir::SHARE_CHUNK * 3];
        *values.last_mut().unwrap() = f64::INFINITY;
        assert!(encode_share_into(&ctx, &codec, &values, 1, 4, &mut pool).is_err());
        // pool still serviceable afterwards
        assert!(encode_share_into(&ctx, &codec, &[1.0, 2.0], 1, 2, &mut pool).is_ok());
    }

    #[test]
    fn secure_mul_public_matches_plain() {
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(3);
        let vals = [2.5, -1.25];
        let shared = share_local_stats(p, &codec, &vals, 0.0, &[], false, &mut rng).unwrap();
        // multiply every center's share by public constant 3
        let c = Fp::new(3);
        let scaled: Vec<Vec<Fp>> = (0..5)
            .map(|j| {
                let mut v = shared.g.per_holder[j].clone();
                secure_mul_public(&mut v, c);
                v
            })
            .collect();
        let quorum: Vec<(usize, &[Fp])> =
            (0..3).map(|j| (j, scaled[j].as_slice())).collect();
        let out = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        assert!((out[0] - 7.5).abs() < 1e-4);
        assert!((out[1] + 3.75).abs() < 1e-4);
    }
}
