//! Secure computation primitives over secret shares (the paper's
//! Algorithm 2 and the multiply-by-public-constant primitive).
//!
//! A computation center never sees plaintext summaries; it holds one
//! share per secret and computes on shares *locally*:
//!
//! * **secure addition** — a center adds its shares of A and B to get
//!   its share of A+B (polynomials add pointwise, the secret is the
//!   constant term);
//! * **secure multiply-by-public** — a center multiplies its share by
//!   a public field constant.
//!
//! [`SecureAccumulator`] is the per-center, per-iteration state that
//! folds institution submissions together as they arrive, so secure
//! aggregation is streaming (O(1) memory in the number of
//! institutions) — this is what makes Fig 4's flat central time hold.

use crate::field::{add_assign_slice, mul_scalar_slice, Fp};
use crate::fixed::FixedCodec;
use crate::shamir::{share_batch_with, ShamirParams, ShareBatch, VandermondeTable};
use crate::util::rng::Rng;

/// Secure addition: combine two share vectors held by the same center.
/// (Algorithm 2, one holder's step.)
#[inline]
pub fn secure_add(acc: &mut [Fp], incoming: &[Fp]) {
    add_assign_slice(acc, incoming);
}

/// Secure multiplication by a public constant, in place.
#[inline]
pub fn secure_mul_public(shares: &mut [Fp], c: Fp) {
    mul_scalar_slice(shares, c);
}

/// Per-center streaming aggregator for one Newton iteration.
///
/// Holds this center's running share of Σ_j g_j, Σ_j dev_j, and (in
/// full-security mode) Σ_j H_j; pragmatic mode accumulates the
/// plaintext Hessian sum instead.
#[derive(Clone, Debug)]
pub struct SecureAccumulator {
    /// Share of the aggregated gradient (d elements).
    pub g: Vec<Fp>,
    /// Share of the aggregated deviance.
    pub dev: Fp,
    /// Share of the aggregated packed Hessian (full mode), if any.
    pub h_shared: Option<Vec<Fp>>,
    /// Plaintext aggregated packed Hessian (pragmatic mode), if any.
    pub h_plain: Option<Vec<f64>>,
    /// Number of submissions folded in.
    pub count: usize,
}

impl SecureAccumulator {
    pub fn new(d: usize, packed_h: usize, full_security: bool) -> Self {
        Self {
            g: vec![Fp::ZERO; d],
            dev: Fp::ZERO,
            h_shared: full_security.then(|| vec![Fp::ZERO; packed_h]),
            h_plain: (!full_security).then(|| vec![0.0; packed_h]),
            count: 0,
        }
    }

    /// Fold in one institution's submission (this center's slice of it).
    pub fn fold(
        &mut self,
        g_share: &[Fp],
        dev_share: Fp,
        hessian: &crate::protocol::HessianPayload,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            g_share.len() == self.g.len(),
            "gradient share length {} != {}",
            g_share.len(),
            self.g.len()
        );
        secure_add(&mut self.g, g_share);
        self.dev = self.dev + dev_share;
        match (hessian, self.h_shared.as_mut(), self.h_plain.as_mut()) {
            (crate::protocol::HessianPayload::Shared(hs), Some(acc), _) => {
                anyhow::ensure!(hs.len() == acc.len(), "hessian share length mismatch");
                secure_add(acc, hs);
            }
            (crate::protocol::HessianPayload::Plain(hp), _, Some(acc)) => {
                anyhow::ensure!(hp.len() == acc.len(), "hessian length mismatch");
                for (a, b) in acc.iter_mut().zip(hp) {
                    *a += b;
                }
            }
            // Pragmatic mode, non-lead center: nothing to fold for H.
            (crate::protocol::HessianPayload::Absent, _, Some(_)) => {}
            _ => anyhow::bail!("hessian payload mode does not match accumulator mode"),
        }
        self.count += 1;
        Ok(())
    }
}

/// Institution-side sharing of one iteration's local summaries.
///
/// Returns, for each center, the triple of payloads it should receive.
/// The share polynomials are drawn from `rng` (must be crypto-grade in
/// deployments; see `util::rng::ChaCha20Rng`).
pub struct SharedStats {
    /// Per-center gradient shares.
    pub g: ShareBatch,
    /// Per-center deviance shares.
    pub dev: ShareBatch,
    /// Per-center packed-Hessian shares (full mode only).
    pub h: Option<ShareBatch>,
}

/// Per-node sharing state hoisted out of the iteration loop: the
/// Shamir parameters plus the precomputed Vandermonde evaluation
/// powers, built once per `(t, w)` and reused for every batch the node
/// ever shares (institutions build one per run).
#[derive(Clone, Debug)]
pub struct ShareContext {
    table: VandermondeTable,
}

impl ShareContext {
    pub fn new(params: ShamirParams) -> Self {
        Self {
            table: VandermondeTable::new(params),
        }
    }

    pub fn params(&self) -> ShamirParams {
        self.table.params()
    }

    /// Share one batch through the cached table.
    pub fn share<R: Rng>(&self, secrets: &[Fp], rng: &mut R) -> ShareBatch {
        share_batch_with(&self.table, secrets, rng)
    }
}

/// Encode-and-share local statistics.
///
/// `g_plain` is the local gradient (d), `dev_plain` the local deviance,
/// `h_packed_plain` the packed upper-triangular Hessian — shared only
/// when `full_security` is set (pragmatic mode sends it plaintext).
///
/// Convenience wrapper building a fresh [`ShareContext`]; the protocol
/// hot path (`institution::run_institution_worker`) caches one context
/// per `(t, w)` scheme across sessions via [`share_local_stats_with`].
pub fn share_local_stats<R: Rng>(
    params: ShamirParams,
    codec: &FixedCodec,
    g_plain: &[f64],
    dev_plain: f64,
    h_packed_plain: &[f64],
    full_security: bool,
    rng: &mut R,
) -> anyhow::Result<SharedStats> {
    share_local_stats_with(
        &ShareContext::new(params),
        codec,
        g_plain,
        dev_plain,
        h_packed_plain,
        full_security,
        rng,
    )
}

/// [`share_local_stats`] through a caller-owned [`ShareContext`] (the
/// allocation for the Vandermonde table happens once per run, not once
/// per iteration).
pub fn share_local_stats_with<R: Rng>(
    ctx: &ShareContext,
    codec: &FixedCodec,
    g_plain: &[f64],
    dev_plain: f64,
    h_packed_plain: &[f64],
    full_security: bool,
    rng: &mut R,
) -> anyhow::Result<SharedStats> {
    let g_enc = codec.encode_slice(g_plain)?;
    let dev_enc = codec.encode(dev_plain)?;
    let g = ctx.share(&g_enc, rng);
    let dev = ctx.share(&[dev_enc], rng);
    let h = if full_security {
        let h_enc = codec.encode_slice(h_packed_plain)?;
        Some(ctx.share(&h_enc, rng))
    } else {
        None
    };
    Ok(SharedStats { g, dev, h })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::HessianPayload;
    use crate::shamir::reconstruct_batch;
    use crate::util::rng::ChaCha20Rng;

    fn params() -> ShamirParams {
        ShamirParams::new(3, 5).unwrap()
    }

    #[test]
    fn streaming_aggregation_equals_plain_sum() {
        // 4 institutions' gradients, shared, folded per center, then the
        // reconstructed aggregate must equal the plaintext sum.
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        let d = 6;
        let grads: Vec<Vec<f64>> = (0..4)
            .map(|j| (0..d).map(|k| (j * d + k) as f64 * 0.25 - 2.0).collect())
            .collect();
        let devs = [10.5, 20.25, 30.0, 5.75];

        let mut accs: Vec<SecureAccumulator> =
            (0..5).map(|_| SecureAccumulator::new(d, 1, false)).collect();
        for (j, g) in grads.iter().enumerate() {
            let shared =
                share_local_stats(p, &codec, g, devs[j], &[1.0], false, &mut rng).unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                acc.fold(
                    &shared.g.per_holder[c],
                    shared.dev.per_holder[c][0],
                    &HessianPayload::Plain(vec![1.0]),
                )
                .unwrap();
            }
        }
        // Reconstruct from 3 of 5 centers.
        let quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
            .iter()
            .map(|&c| (c, accs[c].g.as_slice()))
            .collect();
        let g_total = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        let expect: Vec<f64> = (0..d)
            .map(|k| grads.iter().map(|g| g[k]).sum::<f64>())
            .collect();
        for (a, b) in g_total.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // deviance
        let dev_quorum: Vec<(usize, Fp)> = [1usize, 2, 3]
            .iter()
            .map(|&c| (c, accs[c].dev))
            .collect();
        let dev_total =
            codec.decode(crate::shamir::reconstruct_scalar(p, &dev_quorum).unwrap());
        assert!((dev_total - devs.iter().sum::<f64>()).abs() < 1e-4);
        // plaintext hessian accumulated 4×
        assert!((accs[0].h_plain.as_ref().unwrap()[0] - 4.0).abs() < 1e-12);
        assert_eq!(accs[0].count, 4);
    }

    #[test]
    fn full_mode_shares_hessian_too() {
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(2);
        let h1 = [1.0, 2.0, 3.0];
        let h2 = [0.5, -1.0, 4.0];
        let mut accs: Vec<SecureAccumulator> =
            (0..5).map(|_| SecureAccumulator::new(2, 3, true)).collect();
        for h in [&h1[..], &h2[..]] {
            let shared = share_local_stats(p, &codec, &[0.0, 0.0], 0.0, h, true, &mut rng).unwrap();
            let hs = shared.h.unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                acc.fold(
                    &shared.g.per_holder[c],
                    shared.dev.per_holder[c][0],
                    &HessianPayload::Shared(hs.per_holder[c].clone()),
                )
                .unwrap();
            }
        }
        let quorum: Vec<(usize, &[Fp])> = [0usize, 1, 2]
            .iter()
            .map(|&c| (c, accs[c].h_shared.as_ref().unwrap().as_slice()))
            .collect();
        let h_total = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        for (k, expect) in [1.5, 1.0, 7.0].iter().enumerate() {
            assert!((h_total[k] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn mode_mismatch_is_rejected() {
        let mut acc = SecureAccumulator::new(2, 3, false); // pragmatic
        let err = acc.fold(
            &[Fp::ZERO, Fp::ZERO],
            Fp::ZERO,
            &HessianPayload::Shared(vec![Fp::ZERO; 3]),
        );
        assert!(err.is_err());
        let mut acc = SecureAccumulator::new(2, 3, true); // full
        let err = acc.fold(
            &[Fp::ZERO, Fp::ZERO],
            Fp::ZERO,
            &HessianPayload::Plain(vec![0.0; 3]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn length_mismatch_is_rejected() {
        let mut acc = SecureAccumulator::new(4, 1, false);
        assert!(acc
            .fold(&[Fp::ZERO; 3], Fp::ZERO, &HessianPayload::Plain(vec![0.0]))
            .is_err());
        assert!(acc
            .fold(
                &[Fp::ZERO; 4],
                Fp::ZERO,
                &HessianPayload::Plain(vec![0.0, 1.0])
            )
            .is_err());
    }

    #[test]
    fn secure_mul_public_matches_plain() {
        let p = params();
        let codec = FixedCodec::default();
        let mut rng = ChaCha20Rng::seed_from_u64(3);
        let vals = [2.5, -1.25];
        let shared = share_local_stats(p, &codec, &vals, 0.0, &[], false, &mut rng).unwrap();
        // multiply every center's share by public constant 3
        let c = Fp::new(3);
        let scaled: Vec<Vec<Fp>> = (0..5)
            .map(|j| {
                let mut v = shared.g.per_holder[j].clone();
                secure_mul_public(&mut v, c);
                v
            })
            .collect();
        let quorum: Vec<(usize, &[Fp])> =
            (0..3).map(|j| (j, scaled[j].as_slice())).collect();
        let out = codec.decode_slice(&reconstruct_batch(p, &quorum).unwrap());
        assert!((out[0] - 7.5).abs() < 1e-4);
        assert!((out[1] + 3.75).abs() < 1e-4);
    }
}
