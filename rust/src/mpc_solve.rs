//! Fully-secure matrix inversion over secret shares — the extension
//! the paper explicitly defers: *"Secure matrix inversion can be
//! useful if we want to fully secure intermediate computations (e.g.,
//! inverting the Hessian matrix) … we leave it as future extension."*
//!
//! We implement it with the **Newton–Schulz iteration**
//!
//! ```text
//! X_{k+1} = X_k (2I − A X_k),    X_0 = I / tr(A)
//! ```
//!
//! which converges quadratically to A⁻¹ for SPD A (‖I − A X_0‖ < 1
//! since tr(A) ≥ λ_max for SPD). Every matrix product runs under
//! shares via Beaver triples ([`crate::mpc`]), with fixed-point
//! truncation after each product; `2I` and the trace normalization are
//! handled as public constants (a degree-0 share of a public value is
//! the value itself at every holder).
//!
//! What is revealed: only `tr(A)` — a single aggregate scalar of the
//! GLOBAL Hessian, which the pragmatic protocol exposes in full
//! anyway; everything else stays in the share domain. Combined with a
//! secure mat-vec this yields a Newton *step* where the Hessian never
//! leaves the share domain, completing the paper's "encrypting-all
//! strategy" ablation quantitatively (see the micro bench).
//!
//! Practical envelope: this is a demonstration-grade primitive — the
//! fixed-point budget (frac_bits ≤ 18 here, entries normalized by the
//! trace) targets small d and well-conditioned A. The production
//! protocol never needs it; that is the paper's point, and the triple
//! counts printed by `cargo bench --bench micro_substrates` make the
//! cost gap concrete.

use crate::field::Fp;
use crate::fixed::FixedCodec;
use crate::linalg::Matrix;
use crate::mpc::{SharedMatrix, TriplePool};
use crate::shamir::{share_batch, ShamirParams};
use crate::util::rng::Rng;

/// Recommended codec for secure-solve demonstrations (headroom: the
/// trace-normalized iterates stay O(1); 18 fractional bits keep the
/// doubled-scale products far from the field boundary).
pub fn solve_codec() -> FixedCodec {
    FixedCodec::new(18)
}

/// Truncate every element of a shared vector from `2f` to `f`
/// fractional bits by masked opening (dealer-assisted, same technique
/// as [`TriplePool::mul_fixed`]).
fn truncate_shared<R: Rng>(
    params: ShamirParams,
    codec: &FixedCodec,
    shares: &mut [Vec<Fp>],
    rng: &mut R,
) -> anyhow::Result<()> {
    let f = codec.frac_bits();
    anyhow::ensure!(f <= 22, "truncation needs frac_bits <= 22");
    let w = params.num_holders;
    anyhow::ensure!(shares.len() == w, "share rows != holders");
    let n = shares[0].len();
    let prod_bits = 2 * f + 14;
    let offset: i128 = 1i128 << prod_bits;
    let r_bits = (prod_bits + 9).min(59);
    let off = Fp::from_i128(offset);
    let off_trunc = Fp::from_i128(offset >> f);
    for k in 0..n {
        let r_val: i128 = (((rng.next_u64() as u128) << 64 | rng.next_u64() as u128)
            & ((1u128 << r_bits) - 1)) as i128;
        let sr = share_batch(params, &[Fp::from_i128(r_val)], rng);
        let sr_hi = share_batch(params, &[Fp::from_i128(r_val >> f)], rng);
        let masked: Vec<(usize, Fp)> = (0..w)
            .map(|j| (j, shares[j][k] + off + sr.per_holder[j][0]))
            .collect();
        let opened =
            crate::shamir::reconstruct_scalar(params, &masked[..params.threshold])?;
        let opened_trunc = Fp::from_i128((opened.to_u64() as i128) >> f);
        for (j, row) in shares.iter_mut().enumerate() {
            row[k] = opened_trunc - sr_hi.per_holder[j][0] - off_trunc;
        }
    }
    Ok(())
}

/// Secure fixed-point matrix multiply: raw Beaver matmul then
/// per-element truncation back to `f` fractional bits.
pub fn matmul_fixed<R: Rng>(
    a: &SharedMatrix,
    b: &SharedMatrix,
    params: ShamirParams,
    codec: &FixedCodec,
    pool: &mut TriplePool,
    rng: &mut R,
) -> anyhow::Result<SharedMatrix> {
    let mut c = a.matmul(b, pool)?;
    truncate_shared(params, codec, &mut c.shares, rng)?;
    Ok(c)
}

/// Share a plaintext f64 matrix under the codec.
pub fn share_matrix<R: Rng>(
    params: ShamirParams,
    codec: &FixedCodec,
    m: &Matrix,
    rng: &mut R,
) -> anyhow::Result<SharedMatrix> {
    let enc = codec.encode_slice(&m.data)?;
    Ok(SharedMatrix::share(params, m.rows, m.cols, &enc, rng))
}

/// Open a shared matrix back to f64.
pub fn open_matrix(
    params: ShamirParams,
    codec: &FixedCodec,
    m: &SharedMatrix,
) -> anyhow::Result<Matrix> {
    let vals = codec.decode_slice(&m.open(params)?);
    Ok(Matrix::from_flat(m.rows, m.cols, vals))
}

/// A "shared" representation of a PUBLIC matrix: every holder's share
/// is the encoded value itself (degree-0 polynomial).
fn public_matrix(params: ShamirParams, codec: &FixedCodec, m: &Matrix) -> anyhow::Result<SharedMatrix> {
    let enc = codec.encode_slice(&m.data)?;
    Ok(SharedMatrix {
        rows: m.rows,
        cols: m.cols,
        shares: vec![enc; params.num_holders],
    })
}

/// Elementwise share subtraction: `a − b` (same shape).
fn sub_shared(a: &SharedMatrix, b: &SharedMatrix) -> SharedMatrix {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let shares = a
        .shares
        .iter()
        .zip(&b.shares)
        .map(|(ra, rb)| ra.iter().zip(rb).map(|(&x, &y)| x - y).collect())
        .collect();
    SharedMatrix {
        rows: a.rows,
        cols: a.cols,
        shares,
    }
}

/// Result of a secure inversion.
#[derive(Debug)]
pub struct SecureInverse {
    pub inverse: SharedMatrix,
    /// Newton–Schulz iterations performed.
    pub iterations: usize,
    /// Beaver triples consumed.
    pub triples_used: usize,
    /// The one value opened in plaintext: tr(A).
    pub opened_trace: f64,
}

/// Invert a shared SPD matrix via Newton–Schulz entirely under shares.
///
/// `a` must be shared under [`solve_codec`]-compatible fixed point and
/// be SPD with entries of moderate magnitude. Only `tr(A)` is opened.
pub fn secure_invert_spd<R: Rng>(
    a: &SharedMatrix,
    params: ShamirParams,
    codec: &FixedCodec,
    pool: &mut TriplePool,
    iterations: usize,
    rng: &mut R,
) -> anyhow::Result<SecureInverse> {
    anyhow::ensure!(a.rows == a.cols, "matrix must be square");
    let d = a.rows;
    let before = pool.remaining();

    // Open the trace (sum of diagonal shares is a share of the trace).
    let trace_shares: Vec<(usize, Fp)> = (0..params.num_holders)
        .map(|j| {
            let s = (0..d).map(|i| a.shares[j][i * d + i]).fold(Fp::ZERO, |x, y| x + y);
            (j, s)
        })
        .collect();
    let trace = codec.decode(crate::shamir::reconstruct_scalar(
        params,
        &trace_shares[..params.threshold],
    )?);
    anyhow::ensure!(trace > 0.0, "trace must be positive for SPD input");

    // X0 = I / tr(A) — public.
    let mut x0 = Matrix::zeros(d, d);
    x0.add_diagonal(1.0 / trace);
    let mut x = public_matrix(params, codec, &x0)?;
    let two_i = {
        let mut m = Matrix::zeros(d, d);
        m.add_diagonal(2.0);
        public_matrix(params, codec, &m)?
    };

    for _ in 0..iterations {
        // T = A · X_k  (shared × shared)
        let t = matmul_fixed(a, &x, params, codec, pool, rng)?;
        // U = 2I − T
        let u = sub_shared(&two_i, &t);
        // X_{k+1} = X_k · U
        x = matmul_fixed(&x, &u, params, codec, pool, rng)?;
    }
    Ok(SecureInverse {
        inverse: x,
        iterations,
        triples_used: before - pool.remaining(),
        opened_trace: trace,
    })
}

/// Triples needed for `iters` Newton–Schulz steps at dimension d.
pub fn triples_needed(d: usize, iters: usize) -> usize {
    2 * d * d * d * iters
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::rng::ChaCha20Rng;

    fn spd(d: usize, seed: u64) -> Matrix {
        use crate::util::rng::Rng;
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let mut b = Matrix::zeros(d, d);
        for v in b.data.iter_mut() {
            *v = rng.next_gaussian() * 0.3;
        }
        let mut a = b.transpose().matmul(&b);
        a.add_diagonal(1.0); // well-conditioned, entries O(1)
        a
    }

    #[test]
    fn secure_inverse_matches_cholesky() {
        let params = ShamirParams::new(3, 5).unwrap();
        let codec = solve_codec();
        let mut rng = ChaCha20Rng::seed_from_u64(1);
        for d in [2usize, 3, 4] {
            let a = spd(d, d as u64);
            let iters = 14;
            let mut pool = TriplePool::deal(params, triples_needed(d, iters) + 8, &mut rng);
            let shared_a = share_matrix(params, &codec, &a, &mut rng).unwrap();
            let out =
                secure_invert_spd(&shared_a, params, &codec, &mut pool, iters, &mut rng).unwrap();
            let got = open_matrix(params, &codec, &out.inverse).unwrap();
            let expect = Cholesky::factor(&a).unwrap().inverse();
            let err = got.max_abs_diff(&expect);
            assert!(err < 5e-3, "d={d}: secure inverse off by {err}");
            // verify A·X ≈ I in plaintext
            let prod = a.matmul(&got);
            let eye = Matrix::identity(d);
            assert!(prod.max_abs_diff(&eye) < 1e-2, "d={d}");
        }
    }

    #[test]
    fn only_the_trace_is_opened() {
        // Structural check: the reported opened value equals tr(A) and
        // the inverse arrives still in share form (below-threshold
        // holders cannot read it).
        let params = ShamirParams::new(3, 5).unwrap();
        let codec = solve_codec();
        let mut rng = ChaCha20Rng::seed_from_u64(2);
        let a = spd(3, 9);
        let mut pool = TriplePool::deal(params, triples_needed(3, 10) + 8, &mut rng);
        let shared_a = share_matrix(params, &codec, &a, &mut rng).unwrap();
        let out = secure_invert_spd(&shared_a, params, &codec, &mut pool, 10, &mut rng).unwrap();
        let trace: f64 = (0..3).map(|i| a[(i, i)]).sum();
        assert!((out.opened_trace - trace).abs() < 1e-4);
        // a single holder's decoded view of the inverse is garbage
        let naive = codec.decode_slice(&out.inverse.shares[0]);
        let expect = Cholesky::factor(&a).unwrap().inverse();
        let mut far = 0usize;
        for (v, e) in naive.iter().zip(&expect.data) {
            if (v - e).abs() > 1e3 {
                far += 1;
            }
        }
        assert!(far >= 7, "holder-0's view should be useless, {far}/9 far off");
    }

    #[test]
    fn pool_exhaustion_is_reported() {
        let params = ShamirParams::new(2, 3).unwrap();
        let codec = solve_codec();
        let mut rng = ChaCha20Rng::seed_from_u64(3);
        let a = spd(3, 4);
        let mut pool = TriplePool::deal(params, 5, &mut rng); // far too few
        let shared_a = share_matrix(params, &codec, &a, &mut rng).unwrap();
        let out = secure_invert_spd(&shared_a, params, &codec, &mut pool, 8, &mut rng);
        assert!(out.unwrap_err().to_string().contains("exhausted"));
    }

    #[test]
    fn triple_accounting_matches_prediction() {
        let params = ShamirParams::new(2, 4).unwrap();
        let codec = solve_codec();
        let mut rng = ChaCha20Rng::seed_from_u64(4);
        let d = 2;
        let iters = 3;
        let a = spd(d, 6);
        let mut pool = TriplePool::deal(params, triples_needed(d, iters) + 4, &mut rng);
        let shared_a = share_matrix(params, &codec, &a, &mut rng).unwrap();
        let out =
            secure_invert_spd(&shared_a, params, &codec, &mut pool, iters, &mut rng).unwrap();
        assert_eq!(out.triples_used, triples_needed(d, iters));
    }
}
