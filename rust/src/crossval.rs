//! Secure cross-validation for selecting the regularization parameter.
//!
//! The paper sets λ "a priori or derived via cross-validation"; this
//! module provides the cross-validation without weakening the privacy
//! model. The key observation is the same one that powers the whole
//! protocol: the **held-out deviance is a sum over records** (Eq. 6),
//! so it decomposes per institution and can be aggregated through the
//! identical secure machinery:
//!
//! 1. each institution splits ITS OWN shard into k folds locally (no
//!    cross-institution record movement — the fold pattern is just an
//!    agreed row-index rule);
//! 2. for each fold f and each candidate λ, the consortium fits on
//!    everyone's folds ≠ f via the secure protocol;
//! 3. each institution evaluates the deviance of the resulting β on
//!    its held-out fold f; those local deviances are aggregated (they
//!    are exactly the `dev_j` statistic the protocol already protects);
//! 4. the λ with the lowest mean held-out deviance wins.
//!
//! Implementation note: steps 2/3 run the k fold-fits for each λ as
//! **k concurrent sessions on one persistent
//! [`StudyEngine`](crate::engine::StudyEngine)** — the fold-filtered
//! training views are per-session local data (the fold pattern is an
//! agreed row-index rule each institution applies to its own shard),
//! so every message of the CV procedure is the standard protected
//! protocol — nothing new crosses the network in plaintext, and the
//! network/worker setup is paid once for the whole λ-grid search
//! instead of once per fit.
//!
//! Determinism: fold patterns and per-session share randomness derive
//! from `(master seed, stream)` splitmix forks
//! ([`crate::util::rng::derive_seed`]) with no shared mutable RNG
//! state, so the concurrent fold fits are bit-identical to running the
//! folds one at a time.

use crate::config::ExperimentConfig;
use crate::data::{Dataset, Shard};
use crate::engine::{StudyEngine, SubmitOptions};
use crate::linalg::Matrix;
use crate::model::{local_stats, log_sigmoid};
use crate::util::rng::{derive_seed, Rng, SplitMix64};

/// Result of a λ search.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// Candidates in the order given.
    pub lambdas: Vec<f64>,
    /// Mean held-out (unpenalized) deviance per candidate.
    pub cv_deviance: Vec<f64>,
    /// Index of the winner (min mean deviance).
    pub best: usize,
    /// Final β fitted on ALL data at the winning λ.
    pub beta: Vec<f64>,
}

impl CvResult {
    pub fn best_lambda(&self) -> f64 {
        self.lambdas[self.best]
    }
}

/// Stream tag separating fold-pattern randomness from every other use
/// of the master seed (share polynomials, data synthesis, …).
const FOLD_STREAM: u64 = 0xF01D;

/// Seed for institution `j`'s fold pattern: a pure splitmix fork of
/// `(master seed, institution)` — no shared mutable state, so any
/// fold/session subset reproduces the same pattern in any order.
fn fold_seed(master_seed: u64, institution: usize) -> u64 {
    derive_seed(master_seed, FOLD_STREAM + institution as u64)
}

/// Deterministic per-institution fold assignment: record `i` of a
/// shard belongs to fold `(i + shard_offset) % k` after a seeded
/// per-institution shuffle. Returns per-record fold ids for one shard.
fn fold_assignment(rows: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..rows).collect();
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut idx);
    let mut folds = vec![0usize; rows];
    for (pos, &i) in idx.iter().enumerate() {
        folds[i] = pos % k;
    }
    folds
}

/// Build the training dataset that EXCLUDES fold `f` (per institution),
/// preserving the institution structure.
fn training_view(ds: &Dataset, folds: &[Vec<usize>], f: usize) -> Dataset {
    let d = ds.d();
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut y = Vec::new();
    let mut shards = Vec::with_capacity(ds.num_institutions());
    let mut start = 0usize;
    for j in 0..ds.num_institutions() {
        let s = ds.shards[j];
        for (local_i, i) in (s.start..s.end).enumerate() {
            if folds[j][local_i] != f {
                rows.push(ds.x.row(i).to_vec());
                y.push(ds.y[i]);
            }
        }
        shards.push(Shard {
            start,
            end: rows.len(),
        });
        start = rows.len();
    }
    let _ = d;
    Dataset {
        name: format!("{}-cv-train-f{f}", ds.name),
        x: Matrix::from_rows(rows),
        y,
        shards,
    }
}

/// Held-out (unpenalized) deviance of β on fold `f`, summed across
/// institutions — in deployment each term is computed locally and
/// aggregated through the secure-addition path; numerically the sum is
/// identical, which is what we compute here.
fn holdout_deviance(ds: &Dataset, folds: &[Vec<usize>], f: usize, beta: &[f64]) -> f64 {
    let mut dev = 0.0;
    for j in 0..ds.num_institutions() {
        let s = ds.shards[j];
        for (local_i, i) in (s.start..s.end).enumerate() {
            if folds[j][local_i] == f {
                let z = crate::linalg::dot(ds.x.row(i), beta);
                let yi = ds.y[i];
                dev += -2.0 * (yi * log_sigmoid(z) + (1.0 - yi) * log_sigmoid(-z));
            }
        }
    }
    dev
}

/// k-fold secure cross-validation over a λ grid.
///
/// Runs `k × lambdas.len()` secure fits plus one final fit at the
/// winning λ, all on ONE persistent study engine: for each λ the k
/// fold-fits run as k concurrent sessions sharing the network. The
/// fold split is per-institution (records never move).
pub fn secure_cross_validate(
    ds: &Dataset,
    base_cfg: &ExperimentConfig,
    lambdas: &[f64],
    k: usize,
) -> anyhow::Result<CvResult> {
    anyhow::ensure!(k >= 2, "need at least 2 folds");
    anyhow::ensure!(!lambdas.is_empty(), "empty lambda grid");
    for (j, shard) in ds.shards.iter().enumerate() {
        anyhow::ensure!(
            shard.len() >= k,
            "institution {j} has {} records (< k = {k})",
            shard.len()
        );
    }
    // Per-institution fold patterns: pure functions of (master seed,
    // institution) — see `fold_seed`.
    let folds: Vec<Vec<usize>> = (0..ds.num_institutions())
        .map(|j| fold_assignment(ds.shards[j].len(), k, fold_seed(base_cfg.seed, j)))
        .collect();

    let engine = StudyEngine::for_experiment(ds, base_cfg)?;
    // Materialize each fold's training view ONCE and share its Arc'd
    // shards across the whole λ grid (zero-copy submissions) — the
    // per-λ work is then purely protocol, not dataset rebuilding.
    let fold_shards: Vec<Vec<std::sync::Arc<crate::session::ShardData>>> = (0..k)
        .map(|f| crate::session::ShardData::split(&training_view(ds, &folds, f)))
        .collect();
    let mut cv_dev = vec![0.0; lambdas.len()];
    for (li, &lambda) in lambdas.iter().enumerate() {
        let cfg = ExperimentConfig {
            lambda,
            ..base_cfg.clone()
        };
        // k folds as k concurrent sessions over the shared network —
        // bulk-lane traffic, so a sweep never crowds out interactive
        // studies sharing the engine (and any configured admission cap
        // queues the folds instead of oversubscribing the workers).
        // Explicitly Block on bounded lanes: every fold fit is load-
        // bearing for the CV average, so under backpressure the sweep
        // must wait for lane space, never shed or reject a fold.
        let opts = SubmitOptions::bulk().policy(crate::engine::SubmitPolicy::Block);
        let mut handles = Vec::with_capacity(k);
        for (f, shards) in fold_shards.iter().enumerate() {
            handles.push((f, engine.submit_shared(&cfg, shards.clone(), opts)?));
        }
        for (f, handle) in handles {
            let fit = handle.join()?;
            cv_dev[li] += holdout_deviance(ds, &folds, f, &fit.beta);
        }
    }
    for v in cv_dev.iter_mut() {
        *v /= k as f64;
    }
    let best = cv_dev
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    // Final fit on all data at the winning λ, on the same network; the
    // researcher is waiting on this one, so it rides the interactive
    // lane.
    let cfg = ExperimentConfig {
        lambda: lambdas[best],
        ..base_cfg.clone()
    };
    let fit = engine.submit(&cfg, ds, SubmitOptions::interactive())?.join()?;
    engine.shutdown()?;
    Ok(CvResult {
        lambdas: lambdas.to_vec(),
        cv_deviance: cv_dev,
        best,
        beta: fit.beta,
    })
}

/// Plaintext-centralized CV twin (test oracle): same folds, same grid,
/// centralized Newton fits.
pub fn centralized_cross_validate(
    ds: &Dataset,
    seed: u64,
    tol: f64,
    max_iters: usize,
    lambdas: &[f64],
    k: usize,
) -> anyhow::Result<CvResult> {
    let folds: Vec<Vec<usize>> = (0..ds.num_institutions())
        .map(|j| fold_assignment(ds.shards[j].len(), k, fold_seed(seed, j)))
        .collect();
    let mut cv_dev = vec![0.0; lambdas.len()];
    for f in 0..k {
        let train = training_view(ds, &folds, f);
        for (li, &lambda) in lambdas.iter().enumerate() {
            let fit = crate::baseline::centralized_fit(&train, lambda, tol, max_iters)?;
            cv_dev[li] += holdout_deviance(ds, &folds, f, &fit.beta);
        }
    }
    for v in cv_dev.iter_mut() {
        *v /= k as f64;
    }
    let best = cv_dev
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let fit = crate::baseline::centralized_fit(ds, lambdas[best], tol, max_iters)?;
    Ok(CvResult {
        lambdas: lambdas.to_vec(),
        cv_deviance: cv_dev,
        best,
        beta: fit.beta,
    })
}

/// Sanity metric for tests: deviance of β on a whole dataset.
pub fn full_deviance(ds: &Dataset, beta: &[f64]) -> f64 {
    local_stats(&ds.x, &ds.y, beta).dev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig {
            max_iters: 40,
            ..Default::default()
        }
    }

    #[test]
    fn folds_partition_each_shard() {
        let folds = fold_assignment(103, 5, 7);
        assert_eq!(folds.len(), 103);
        let mut counts = [0usize; 5];
        for &f in &folds {
            assert!(f < 5);
            counts[f] += 1;
        }
        // balanced within 1
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn training_view_excludes_exactly_one_fold() {
        let ds = synthetic("t", 300, 4, 3, 0.0, 1.0, 5);
        let folds: Vec<Vec<usize>> = (0..3)
            .map(|j| fold_assignment(ds.shards[j].len(), 3, j as u64))
            .collect();
        let total_f0: usize = folds.iter().map(|f| f.iter().filter(|&&x| x == 0).count()).sum();
        let train = training_view(&ds, &folds, 0);
        assert_eq!(train.n(), 300 - total_f0);
        assert_eq!(train.num_institutions(), 3);
        // shards stay contiguous and cover the training rows
        let covered: usize = train.shards.iter().map(|s| s.len()).sum();
        assert_eq!(covered, train.n());
    }

    #[test]
    fn fold_seeds_are_deterministic_without_shared_state() {
        // Fold patterns are pure functions of (master seed, institution):
        // evaluating institutions in any order — or any subset — yields
        // the same assignment, which is what lets k folds run as k
        // concurrent sessions without a shared mutable RNG.
        let forward: Vec<Vec<usize>> = (0..4)
            .map(|j| fold_assignment(97, 5, fold_seed(42, j)))
            .collect();
        let mut backward: Vec<Vec<usize>> = (0..4)
            .rev()
            .map(|j| fold_assignment(97, 5, fold_seed(42, j)))
            .collect();
        backward.reverse();
        assert_eq!(forward, backward);
        // Distinct institutions get distinct patterns; distinct master
        // seeds reshuffle.
        assert_ne!(forward[0], forward[1]);
        assert_ne!(
            fold_assignment(97, 5, fold_seed(42, 0)),
            fold_assignment(97, 5, fold_seed(43, 0))
        );
    }

    #[test]
    fn secure_cv_matches_centralized_cv() {
        let ds = synthetic("t", 600, 4, 3, 0.0, 1.0, 9);
        let lambdas = [0.1, 1.0, 10.0];
        let cfg = base_cfg();
        let secure = secure_cross_validate(&ds, &cfg, &lambdas, 3).unwrap();
        let central =
            centralized_cross_validate(&ds, cfg.seed, cfg.tol, cfg.max_iters, &lambdas, 3)
                .unwrap();
        assert_eq!(secure.best, central.best, "same winner");
        for (a, b) in secure.cv_deviance.iter().zip(&central.cv_deviance) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        for (a, b) in secure.beta.iter().zip(&central.beta) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn cv_prefers_moderate_lambda_on_noisy_small_data() {
        // With few records and many features, λ→0 overfits: its held-out
        // deviance must exceed the best λ's.
        let ds = synthetic("t", 120, 10, 3, 0.0, 1.0, 11);
        let lambdas = [1e-6, 1.0, 5.0];
        let cfg = base_cfg();
        let cv = secure_cross_validate(&ds, &cfg, &lambdas, 4).unwrap();
        assert!(
            cv.cv_deviance[0] > cv.cv_deviance[cv.best] - 1e-9,
            "unregularized should not win by luck: {:?}",
            cv.cv_deviance
        );
        assert!(cv.best_lambda() > 1e-6);
    }

    #[test]
    fn cv_under_admission_cap_is_bit_identical_to_uncapped() {
        // The fold sessions ride the bulk lane; capping in-flight
        // sessions to 1 serializes them completely — and must change
        // NOTHING numerically (same session ids, same share streams).
        let ds = synthetic("t", 240, 3, 3, 0.0, 1.0, 13);
        let lambdas = [0.1, 1.0];
        let cfg = base_cfg();
        let free = secure_cross_validate(&ds, &cfg, &lambdas, 3).unwrap();
        let capped_cfg = ExperimentConfig { max_in_flight: 1, ..cfg };
        let capped = secure_cross_validate(&ds, &capped_cfg, &lambdas, 3).unwrap();
        assert_eq!(free.best, capped.best);
        assert_eq!(free.cv_deviance, capped.cv_deviance, "bitwise CV deviances");
        assert_eq!(free.beta, capped.beta, "bitwise final β");
    }

    #[test]
    fn cv_on_sharded_backpressured_engine_is_bit_identical() {
        // Fold fits survive the full control plane at once: 4 driver
        // shards, an admission cap of 2, and single-slot bulk lanes
        // (so the λ-grid submissions actually block for space). The CV
        // outcome must not move by a bit.
        let ds = synthetic("t", 240, 3, 3, 0.0, 1.0, 13);
        let lambdas = [0.1, 1.0];
        let cfg = base_cfg();
        let free = secure_cross_validate(&ds, &cfg, &lambdas, 3).unwrap();
        let hard_cfg = ExperimentConfig {
            driver_shards: 4,
            max_in_flight: 2,
            lane_capacity: 1,
            ..cfg
        };
        let hard = secure_cross_validate(&ds, &hard_cfg, &lambdas, 3).unwrap();
        assert_eq!(free.best, hard.best);
        assert_eq!(free.cv_deviance, hard.cv_deviance, "bitwise CV deviances");
        assert_eq!(free.beta, hard.beta, "bitwise final β");
    }

    #[test]
    fn cv_validates_inputs() {
        let ds = synthetic("t", 30, 3, 3, 0.0, 1.0, 12);
        let cfg = base_cfg();
        assert!(secure_cross_validate(&ds, &cfg, &[1.0], 1).is_err()); // k < 2
        assert!(secure_cross_validate(&ds, &cfg, &[], 3).is_err()); // empty grid
        // k larger than a shard
        assert!(secure_cross_validate(&ds, &cfg, &[1.0], 11).is_err());
    }
}
