//! Multi-process smoke test for `privlr serve` (`--features net`):
//! REAL subprocesses of the built binary — one coordinator, three
//! centers, two institutions — wired over loopback TCP, each deriving
//! its session specs locally from the shared CLI config (specs never
//! cross the wire).
//!
//! Two gates:
//!
//! * **Bit-identity** — the coordinator process's released β̂ (parsed
//!   from its machine-readable `bits=` output) is byte-identical to an
//!   in-memory fit of the same config, across K=2 sessions.
//! * **DP across processes** — with `--dp-epsilon` the six processes
//!   jointly sample release noise as shares; the released β̂ carries
//!   calibrated noise (within the mechanism's envelope of the plain
//!   β̂) yet is NOT reproducible from the shared config — each
//!   institution keys its partial from its own OS entropy, so an
//!   in-memory DP fit of the identical config yields a different
//!   release. Config-derivable noise would let any participant strip
//!   it.

#![cfg(feature = "net")]

use privlr::config::{DatasetSpec, ExperimentConfig};
use privlr::engine::{StudyEngine, SubmitOptions};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Reserve a loopback address: bind an ephemeral listener, read the
/// port, release it. (The usual pre-agreed-port trick; the tiny reuse
/// race is acceptable for a smoke test.)
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let a = l.local_addr().unwrap();
    drop(l);
    a.to_string()
}

fn shared_cfg() -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetSpec::Synthetic { n: 600, d: 4, institutions: 2 },
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        seed: 904,
        ..ExperimentConfig::default()
    }
}

/// The CLI flags encoding [`shared_cfg`] — every process derives the
/// same specs from these.
fn shared_flags(sessions: u32, dp: bool) -> Vec<String> {
    let mut f: Vec<String> = [
        "--dataset",
        "synthetic:600:4:2",
        "--centers",
        "3",
        "--threshold",
        "2",
        "--max-iters",
        "30",
        "--seed",
        "904",
        "--engine",
        "rust",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    f.push("--sessions".into());
    f.push(sessions.to_string());
    if dp {
        f.push("--dp-epsilon".into());
        f.push("1.0".into());
    }
    f
}

fn spawn_member(role: &str, id: usize, listen: &str, peers: &[String], flags: &[String]) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_privlr"));
    cmd.arg("serve")
        .arg("--role")
        .arg(role)
        .arg("--id")
        .arg(id.to_string())
        .arg("--listen")
        .arg(listen)
        .args(flags)
        .stdin(Stdio::null())
        .stderr(Stdio::inherit());
    if !peers.is_empty() {
        cmd.arg("--peers").arg(peers.join(","));
    }
    // Workers' stdout is uninteresting; the coordinator's is parsed.
    cmd.stdout(if role == "coordinator" { Stdio::piped() } else { Stdio::null() });
    cmd.spawn().unwrap_or_else(|e| panic!("spawning {role} {id}: {e}"))
}

/// Reap a worker with a bound: the coordinator's engine shutdown ships
/// `Shutdown` over the wire, so workers exit on their own shortly
/// after — a worker still alive after the grace period is a bug (and
/// gets killed so the test run never leaks processes).
fn reap(mut child: Child, what: &str) {
    let t0 = Instant::now();
    loop {
        match child.try_wait().unwrap() {
            Some(status) => {
                assert!(status.success(), "{what} exited with {status}");
                return;
            }
            None if t0.elapsed() > Duration::from_secs(30) => {
                child.kill().ok();
                child.wait().ok();
                panic!("{what} never observed the over-the-wire shutdown");
            }
            None => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Launch the six-process consortium, run `sessions` fits, and return
/// each session's β̂ recovered from the coordinator's `bits=` output.
fn run_consortium(sessions: u32, dp: bool, d: usize) -> Vec<Vec<f64>> {
    let flags = shared_flags(sessions, dp);
    let coord_addr = free_addr();
    let center_addrs: Vec<String> = (0..3).map(|_| free_addr()).collect();

    let coordinator = spawn_member("coordinator", 0, &coord_addr, &[], &flags);
    let mut workers = Vec::new();
    for (c, addr) in center_addrs.iter().enumerate() {
        workers.push(spawn_member("center", c, addr, &[coord_addr.clone()], &flags));
    }
    for j in 0..2 {
        let mut peers = vec![coord_addr.clone()];
        peers.extend(center_addrs.iter().cloned());
        workers.push(spawn_member("institution", j, "127.0.0.1:0", &peers, &flags));
    }

    // The coordinator blocks until every peer dials in (bounded
    // in-process at 120s), runs the sessions, ships Shutdown, exits.
    let out = coordinator.wait_with_output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    if !out.status.success() {
        for w in workers {
            let mut w = w;
            w.kill().ok();
            w.wait().ok();
        }
        panic!("coordinator failed ({}):\n{stdout}", out.status);
    }
    for (i, w) in workers.into_iter().enumerate() {
        reap(w, &format!("worker {i}"));
    }

    // Recover the released coefficients bit-exactly from the
    // machine-readable output.
    let bits: Vec<f64> = stdout
        .lines()
        .filter_map(|l| l.split("bits=").nth(1))
        .map(|hex| f64::from_bits(u64::from_str_radix(hex.trim(), 16).unwrap()))
        .collect();
    assert_eq!(
        bits.len(),
        sessions as usize * d,
        "expected {sessions}×{d} coefficient lines in:\n{stdout}"
    );
    bits.chunks(d).map(<[f64]>::to_vec).collect()
}

/// In-memory reference fits: session ids 1..=K on a fresh engine — the
/// same ids the serve workers pre-register, so every share and noise
/// stream derives from identical `(seed, session, institution)` triples.
fn in_memory_betas(cfg: &ExperimentConfig, sessions: u32) -> Vec<Vec<f64>> {
    let ds = cfg.dataset.load(cfg.seed).unwrap();
    let engine = StudyEngine::new(ds.num_institutions(), cfg.num_centers).unwrap();
    let handles: Vec<_> = (0..sessions)
        .map(|_| engine.submit(cfg, &ds, SubmitOptions::batch()).unwrap())
        .collect();
    let betas = handles.into_iter().map(|h| h.join().unwrap().beta).collect();
    engine.shutdown().unwrap();
    betas
}

/// Six real processes over loopback TCP reconstruct the same bytes the
/// in-memory transport does — K=2 sessions, plain release.
#[test]
fn serve_processes_fit_bit_identically_to_in_memory() {
    let cfg = shared_cfg();
    let base = in_memory_betas(&cfg, 2);
    let served = run_consortium(2, false, 4);
    for (s, (a, b)) in served.iter().zip(&base).enumerate() {
        let same = a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "session {}: serve β̂ {a:?} != in-memory β̂ {b:?}", s + 1);
    }
}

/// The DP release round works across REAL process boundaries: the six
/// processes jointly sample the noise as shares and the released β̂
/// carries calibrated noise. Because every institution keys its
/// partial from its own OS entropy, the release must differ BOTH from
/// the non-private β̂ AND from an in-memory DP fit of the identical
/// config — a release reproducible from config alone would mean any
/// participant could recompute the noise and subtract it.
#[test]
fn serve_processes_release_dp_beta_with_underivable_noise() {
    let mut cfg = shared_cfg();
    let plain = in_memory_betas(&cfg, 1);
    cfg.dp = Some(privlr::dp::DpConfig::default());
    let local_dp = in_memory_betas(&cfg, 1);
    let served = run_consortium(1, true, 4);

    // Calibrated envelope: each of the S = 2 institutions alone
    // supplies the full N(0, σ²) partial under the default
    // min_honest = 1, so the summed noise has std σ·√2; 12 of those
    // per coordinate bounds the release without flaking (false-failure
    // ≈ 1e-32 per coordinate).
    let sigma = privlr::dp::DpConfig::default()
        .params_for_fit(600, cfg.lambda, 2)
        .unwrap()
        .gaussian_sigma();
    let envelope = 12.0 * sigma * 2f64.sqrt();
    for (k, (&s, &p)) in served[0].iter().zip(&plain[0]).enumerate() {
        assert!(s.is_finite(), "released coordinate {k} not finite: {s}");
        assert!(
            (s - p).abs() <= envelope,
            "coordinate {k}: |served − plain| = {} outside the {envelope:.1} noise envelope",
            (s - p).abs()
        );
    }
    assert_ne!(
        served[0], plain[0],
        "the DP release must differ from the non-private β̂"
    );
    assert_ne!(
        served[0], local_dp[0],
        "a DP release reproducible from the shared config alone means every participant \
         can recompute and strip the noise — the nonces must come from local entropy"
    );
}
