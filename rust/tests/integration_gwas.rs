//! End-to-end acceptance gate for the GWAS screening fast path:
//! secure score-test screening + full-fit-on-hits must reach exactly
//! the decisions exhaustive full fitting reaches — same hit set,
//! bit-identical β̂ on every hit — on a synthetic panel with planted
//! effects, across driver shard counts {1, 2, 4}.
//!
//! The promotion threshold is placed in the middle of the gap between
//! the strongest non-hit and the weakest hit of the PLAINTEXT score
//! statistics, so the codec-precision difference between the secure
//! statistic and the plaintext one cannot flip a decision — the gate
//! then demands exact hit-set equality, not approximate agreement.

use privlr::config::ExperimentConfig;
use privlr::data::{synthetic_panel, SnpPanel};
use privlr::engine::{StudyEngine, SubmitOptions, SubmitPolicy};
use privlr::model::{snp_screen_stats_reference, NullModelCache, ScreenShard};
use privlr::session::ShardData;
use privlr::simd::Isa;
use std::sync::Arc;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        max_iters: 50,
        num_centers: 3,
        threshold: 2,
        ..ExperimentConfig::default()
    }
}

fn panel() -> Arc<SnpPanel> {
    Arc::new(synthetic_panel("gwas-gate", 600, 4, 2, 24, 3, 1.2, 77))
}

/// Plaintext score statistics for every SNP: per-shard reference
/// kernels summed in institution order through the given null cache.
fn plaintext_stats(panel: &SnpPanel, null: &NullModelCache) -> Vec<f64> {
    let d = panel.d();
    let shards: Vec<ScreenShard> = panel
        .shard_data()
        .iter()
        .map(|sh| ScreenShard::build(&sh.x, &sh.y, &null.beta, Isa::Scalar))
        .collect();
    (0..panel.num_snps())
        .map(|s| {
            let (mut u, mut b, mut q) = (0.0f64, vec![0.0f64; d], 0.0f64);
            for (j, scr) in shards.iter().enumerate() {
                let (uj, bj, qj) =
                    snp_screen_stats_reference(&panel.shard_data()[j].x, scr, panel.snp_shard(s, j));
                u += uj;
                q += qj;
                for (acc, v) in b.iter_mut().zip(&bj) {
                    *acc += v;
                }
            }
            null.score_test(u, &b, q).0
        })
        .collect()
}

/// Fit the null model securely on `engine` and build the cache from
/// the fit's reconstructed Fisher block — the deployment path, no
/// plaintext shortcut.
fn secure_null(engine: &StudyEngine, cfg: &ExperimentConfig, panel: &SnpPanel) -> Arc<NullModelCache> {
    let fit = engine
        .submit_shared(cfg, panel.shard_data().to_vec(), SubmitOptions::interactive())
        .unwrap()
        .join()
        .unwrap();
    let fisher = fit.fisher.as_ref().expect("full fit carries fisher");
    Arc::new(NullModelCache::new(fit.beta.clone(), fisher, cfg.lambda).unwrap())
}

#[test]
fn screening_reaches_exhaustive_full_fit_decisions_across_shards() {
    let panel = panel();
    let cfg = base_cfg();

    // ---- exhaustive arm (single-shard engine, ground truth) ----
    let engine = StudyEngine::for_experiment(&panel.covariates, &cfg).unwrap();
    let null = secure_null(&engine, &cfg, &panel);

    // Place the threshold mid-gap between the 3rd and 4th strongest
    // plaintext statistics: the hit set is exactly the top 3, with a
    // decision margin far beyond codec precision. A *plaintext* cache
    // twin (same β̂₀/Fisher, both from the secure null fit) keyed the
    // statistics, so the two arms share one decision rule.
    let stats = plaintext_stats(&panel, &null);
    let mut sorted = stats.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let threshold = 0.5 * (sorted[2] + sorted[3]);
    assert!(
        sorted[2] - sorted[3] > 1.0,
        "degenerate fixture: no decision gap ({} vs {})",
        sorted[2],
        sorted[3]
    );
    // Sanity: the planted causal SNPs are the top 3.
    let mut expected_hits: Vec<u32> = stats
        .iter()
        .enumerate()
        .filter(|(_, &c)| c >= threshold)
        .map(|(s, _)| s as u32)
        .collect();
    expected_hits.sort_unstable();
    assert_eq!(
        expected_hits,
        panel.causal.iter().map(|&c| c as u32).collect::<Vec<_>>(),
        "planted effects must dominate the screen"
    );

    // Exhaustively full-fit EVERY SNP; keep β̂ of the expected hits.
    let mut exhaustive_betas: Vec<Vec<f64>> = Vec::new();
    for s in 0..panel.num_snps() {
        let ds = panel.full_fit_dataset(s);
        let fit = engine
            .submit_shared(&cfg, ShardData::split(&ds), SubmitOptions::default())
            .unwrap()
            .join()
            .unwrap();
        assert_eq!(fit.beta.len(), panel.d() + 1);
        if expected_hits.contains(&(s as u32)) {
            exhaustive_betas.push(fit.beta);
        }
    }
    engine.shutdown().unwrap();

    // ---- screening arm, at driver shards ∈ {1, 2, 4} ----
    for shards in [1usize, 2, 4] {
        let mut cfg = base_cfg();
        cfg.driver_shards = shards;
        let engine = StudyEngine::for_experiment(&panel.covariates, &cfg).unwrap();
        let null = secure_null(&engine, &cfg, &panel);
        let report = engine
            .screen_sweep(
                &cfg,
                &panel,
                &null,
                threshold,
                4,
                SubmitOptions::bulk().policy(SubmitPolicy::ShedOldestBulk),
            )
            .unwrap();
        engine.shutdown().unwrap();
        // Unbounded lanes: full coverage, nothing shed.
        assert_eq!(report.shed, 0, "shards={shards}");
        assert_eq!(report.screened, panel.num_snps(), "shards={shards}");
        // Identical hit set…
        let hit_snps: Vec<u32> = report.hits.iter().map(|h| h.snp).collect();
        assert_eq!(hit_snps, expected_hits, "shards={shards}");
        // …and bit-identical β̂ on every hit vs the exhaustive arm.
        for (h, exhaustive) in report.hits.iter().zip(&exhaustive_betas) {
            assert_eq!(h.fit.beta.len(), exhaustive.len());
            for (a, b) in h.fit.beta.iter().zip(exhaustive) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shards={shards} snp={} β̂ diverged",
                    h.snp
                );
            }
        }
    }
}

/// The screen's traffic invariant: a score-screen session moves O(d)
/// per institution per center — never a packed Hessian — and its
/// per-session bytes are attributed exactly like a fit's.
#[test]
fn screen_sessions_are_o_d_on_the_wire() {
    let panel = panel();
    let cfg = base_cfg();
    let engine = StudyEngine::for_experiment(&panel.covariates, &cfg).unwrap();
    let null = secure_null(&engine, &cfg, &panel);
    let screen_fit = engine
        .submit_screen(&cfg, &panel, &null, 0, SubmitOptions::default())
        .unwrap()
        .join()
        .unwrap();
    let full = panel.full_fit_dataset(0);
    let full_fit = engine
        .submit_shared(&cfg, ShardData::split(&full), SubmitOptions::default())
        .unwrap()
        .join()
        .unwrap();
    engine.shutdown().unwrap();
    // One screen round moves far less than one full fit (which carries
    // a packed (d+1)(d+2)/2 Hessian per institution per center per
    // iteration). The screen's whole session — submissions, aggregate,
    // teardown — must stay under a single full-fit iteration's
    // submission traffic.
    let screen_bytes = screen_fit.metrics.traffic.total_bytes;
    let full_bytes = full_fit.metrics.traffic.total_bytes;
    assert!(
        screen_bytes * 4 < full_bytes,
        "screen session moved {screen_bytes} bytes vs full fit {full_bytes}"
    );
    assert!(screen_fit.screen.is_some());
    assert_eq!(screen_fit.metrics.iterations, 1);
}
