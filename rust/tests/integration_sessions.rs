//! Session-engine integration: K concurrent fits over ONE persistent
//! network must be bit-identical — β, deviance traces, iteration
//! counts, and per-session traffic — to the same fits run
//! sequentially, and per-session traffic counters must sum to the
//! global counters. This is the acceptance gate of the
//! session-multiplexed refactor.

use privlr::config::{ExperimentConfig, SecurityMode};
use privlr::coordinator::{secure_fit, SecureFitResult};
use privlr::data::{synthetic, Dataset};
use privlr::engine::{
    EngineOptions, Lifecycle, Priority, StudyEngine, SubmitOptions, SubmitPolicy,
};

/// Five heterogeneous studies sharing one topology (3 institutions,
/// 5 centers, t=3): different data, λ, tolerance and security modes —
/// and different dimensions, which exercises per-session worker state.
fn studies() -> Vec<(Dataset, ExperimentConfig)> {
    let base = ExperimentConfig {
        max_iters: 30,
        ..ExperimentConfig::default()
    };
    vec![
        (
            synthetic("a", 900, 4, 3, 0.0, 1.0, 301),
            ExperimentConfig { lambda: 1.0, ..base.clone() },
        ),
        (
            synthetic("b", 600, 6, 3, 0.0, 1.0, 302),
            ExperimentConfig { lambda: 0.1, ..base.clone() },
        ),
        (
            synthetic("c", 1200, 5, 3, 0.5, 1.5, 303),
            ExperimentConfig {
                lambda: 10.0,
                mode: SecurityMode::Full,
                ..base.clone()
            },
        ),
        (
            synthetic("d", 400, 3, 3, 0.0, 1.0, 304),
            ExperimentConfig { lambda: 2.5, seed: 77, ..base.clone() },
        ),
        (
            synthetic("e", 750, 6, 3, -0.3, 0.8, 305),
            ExperimentConfig {
                lambda: 0.01,
                mode: SecurityMode::Full,
                tol: 1e-8,
                ..base
            },
        ),
    ]
}

fn assert_bit_identical(a: &SecureFitResult, b: &SecureFitResult, label: &str) {
    assert_eq!(a.beta, b.beta, "{label}: β must be bit-identical");
    assert_eq!(
        a.metrics.deviance_trace, b.metrics.deviance_trace,
        "{label}: deviance traces must be bit-identical"
    );
    assert_eq!(
        a.metrics.iterations, b.metrics.iterations,
        "{label}: iteration counts must match"
    );
}

#[test]
fn concurrent_sessions_match_sequential_bitwise() {
    let studies = studies();
    assert!(studies.len() >= 4, "acceptance requires K >= 4 sessions");

    // Sequential: one persistent engine, one session at a time.
    let seq_engine = StudyEngine::new(3, 5).unwrap();
    let sequential: Vec<SecureFitResult> = studies
        .iter()
        .map(|(ds, cfg)| seq_engine.submit(cfg, ds, SubmitOptions::default()).unwrap().join().unwrap())
        .collect();
    seq_engine.shutdown().unwrap();

    // Concurrent: a fresh engine, all K sessions in flight together.
    let con_engine = StudyEngine::new(3, 5).unwrap();
    let handles: Vec<_> = studies
        .iter()
        .map(|(ds, cfg)| con_engine.submit(cfg, ds, SubmitOptions::default()).unwrap())
        .collect();
    // Session ids match the sequential run (1..=K in submission order).
    for (i, h) in handles.iter().enumerate() {
        assert_eq!(h.session_id(), (i + 1) as u32);
    }
    let concurrent: Vec<SecureFitResult> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let global = con_engine.traffic();
    con_engine.shutdown().unwrap();

    for (i, (seq, con)) in sequential.iter().zip(&concurrent).enumerate() {
        assert_bit_identical(seq, con, &format!("study {i}"));
        // Per-session traffic is deterministic too: the same frames
        // cross the network whether the session ran alone or among K.
        assert_eq!(
            seq.metrics.traffic.total_bytes, con.metrics.traffic.total_bytes,
            "study {i}: per-session byte totals"
        );
        assert_eq!(
            seq.metrics.traffic.total_messages, con.metrics.traffic.total_messages,
            "study {i}: per-session message counts"
        );
        assert_eq!(
            seq.metrics.traffic.submission_bytes, con.metrics.traffic.submission_bytes,
            "study {i}: submission attribution"
        );
        assert!(con.metrics.iterations > 1, "study {i} trivially converged");
    }

    // Per-session counters sum to the global counters.
    let session_sum: u64 = global.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(session_sum, global.total_bytes);
    // ... and each session's slice matches its own metrics.
    for (i, con) in concurrent.iter().enumerate() {
        let sid = (i + 1) as u32;
        assert_eq!(
            global.session_bytes(sid),
            con.metrics.traffic.total_bytes,
            "study {i}: global per-session entry"
        );
    }
}

#[test]
fn engine_sessions_match_the_single_fit_compat_path() {
    // The compat path (secure_fit: throwaway engine, one session) and
    // an engine session must agree bitwise — reconstruction is exact in
    // the field, so even different session ids (hence different share
    // polynomials) cannot move β.
    let (ds, cfg) = &studies()[1];
    let compat = secure_fit(ds, cfg).unwrap();
    let engine = StudyEngine::new(3, 5).unwrap();
    // Burn a session id so the engine session's share streams differ
    // from the compat run's — the fit must not care.
    let warmup = engine.submit(cfg, ds, SubmitOptions::default()).unwrap();
    warmup.join().unwrap();
    let fit = engine.submit(cfg, ds, SubmitOptions::default()).unwrap().join().unwrap();
    engine.shutdown().unwrap();
    assert_bit_identical(&compat, &fit, "compat-vs-engine");
}

#[test]
fn many_sessions_reuse_one_network_cheaply() {
    // 8 concurrent sessions of the same study on one engine: all agree
    // bitwise with each other (same master seed ⇒ same data; share
    // streams differ per session but reconstruction is exact).
    let ds = synthetic("t", 500, 4, 2, 0.0, 1.0, 400);
    let cfg = ExperimentConfig {
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        ..ExperimentConfig::default()
    };
    let engine = StudyEngine::new(2, 3).unwrap();
    // Zero-copy path: all 8 sessions share one set of Arc'd shards.
    let shards = privlr::session::ShardData::split(&ds);
    let handles: Vec<_> = (0..8)
        .map(|_| engine.submit_shared(&cfg, shards.clone(), SubmitOptions::default()).unwrap())
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let global = engine.traffic();
    engine.shutdown().unwrap();
    for r in &results[1..] {
        assert_bit_identical(&results[0], r, "replica");
    }
    // 8 sessions + nothing else: exactly 8 per-session entries (no
    // control traffic until shutdown, which happened after snapshot).
    assert_eq!(global.per_session.len(), 8);
    let sum: u64 = global.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(sum, global.total_bytes);
}

/// Acceptance gate of the control-plane refactor: the concurrent ≡
/// sequential bit-identity guarantee survives priority scheduling AND
/// an admission cap of `max_in_flight < K` — the scheduler may move
/// wall-clock interleaving but never per-session numerics.
#[test]
fn capped_priority_scheduling_preserves_bit_identity() {
    let studies = studies();
    let k = studies.len();
    assert!(k >= 4, "acceptance requires K >= 4 sessions");

    // Sequential baseline: one persistent engine, one session at a time.
    let seq_engine = StudyEngine::new(3, 5).unwrap();
    let sequential: Vec<SecureFitResult> = studies
        .iter()
        .map(|(ds, cfg)| {
            seq_engine
                .submit(cfg, ds, SubmitOptions::default())
                .unwrap()
                .join()
                .unwrap()
        })
        .collect();
    seq_engine.shutdown().unwrap();

    // Capped + prioritized: all K submitted at once, only 2 admitted at
    // a time, with priorities cycling across all three lanes.
    let lanes = [
        Priority::Bulk,
        Priority::Interactive,
        Priority::Batch,
        Priority::Interactive,
        Priority::Bulk,
    ];
    let capped_engine = StudyEngine::with_options(
        3,
        5,
        EngineOptions { max_in_flight: 2, ..Default::default() },
    )
    .unwrap();
    let handles: Vec<_> = studies
        .iter()
        .zip(lanes)
        .map(|((ds, cfg), priority)| {
            capped_engine
                .submit(cfg, ds, SubmitOptions::with_priority(priority))
                .unwrap()
        })
        .collect();
    let capped: Vec<SecureFitResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // The cap actually bit: never more than 2 in flight.
    assert!(capped_engine.peak_in_flight() <= 2, "admission cap violated");
    assert!(capped_engine.peak_in_flight() >= 1);
    // Every session walked the full lifecycle to Closed and the workers
    // hold zero per-session state.
    for i in 0..k {
        assert_eq!(
            capped_engine.lifecycle((i + 1) as u32),
            Some(Lifecycle::Closed),
            "study {i}"
        );
    }
    assert!(capped_engine.worker_live_sessions().iter().all(|&n| n == 0));
    assert_eq!(capped_engine.live_specs(), 0);
    capped_engine.shutdown().unwrap();

    for (i, (seq, cap)) in sequential.iter().zip(&capped).enumerate() {
        assert_bit_identical(seq, cap, &format!("capped study {i}"));
        assert_eq!(
            seq.metrics.traffic.total_bytes, cap.metrics.traffic.total_bytes,
            "study {i}: per-session byte totals under the cap"
        );
    }
}

/// Acceptance gate of the sharded-engine refactor: fits under
/// `driver_shards ∈ {1, 2, 4}` — capped, prioritized, AND running
/// through bounded lanes with blocking backpressure — are
/// byte-identical to the single-driver sequential reference, and the
/// per-shard leak gate reads zero live worker state after drain.
#[test]
fn sharded_backpressured_engines_match_single_driver_bitwise() {
    let studies = studies();
    let k = studies.len();
    assert!(k >= 4, "acceptance requires K >= 4 sessions");

    // Single-driver sequential reference.
    let seq_engine = StudyEngine::new(3, 5).unwrap();
    let sequential: Vec<SecureFitResult> = studies
        .iter()
        .map(|(ds, cfg)| {
            seq_engine
                .submit(cfg, ds, SubmitOptions::default())
                .unwrap()
                .join()
                .unwrap()
        })
        .collect();
    seq_engine.shutdown().unwrap();

    let lanes = [
        Priority::Bulk,
        Priority::Interactive,
        Priority::Batch,
        Priority::Interactive,
        Priority::Bulk,
    ];
    for shards_n in [1usize, 2, 4] {
        // Single-slot lanes arm the Block policy: two studies share
        // the interactive lane (and two the bulk lane), so whenever
        // the driver hasn't drained the earlier one yet, the later
        // same-lane submission must wait for space. Whether a given
        // run actually blocks depends on scheduling — which is the
        // point: backpressure may move wall-clock, never results.
        let engine = StudyEngine::with_options(
            3,
            5,
            EngineOptions {
                max_in_flight: 2,
                driver_shards: shards_n,
                lane_capacity: 1,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(engine.driver_shards(), shards_n);
        let handles: Vec<_> = studies
            .iter()
            .zip(lanes)
            .map(|((ds, cfg), priority)| {
                engine
                    .submit(
                        cfg,
                        ds,
                        SubmitOptions::with_priority(priority).policy(SubmitPolicy::Block),
                    )
                    .unwrap()
            })
            .collect();
        let results: Vec<SecureFitResult> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();

        assert!(
            engine.peak_in_flight() <= 2,
            "global admission cap violated at {shards_n} shards"
        );
        for (i, (seq, got)) in sequential.iter().zip(&results).enumerate() {
            assert_bit_identical(seq, got, &format!("study {i} at {shards_n} shards"));
            assert_eq!(
                seq.metrics.traffic.total_bytes, got.metrics.traffic.total_bytes,
                "study {i} at {shards_n} shards: per-session byte totals"
            );
            // Queue-wait is surfaced for every admitted session.
            assert!(got.metrics.queue_secs >= 0.0);
            assert!(engine.queue_wait((i + 1) as u32).is_some());
        }
        // Per-shard leak gate: every session terminal, zero live
        // worker state, zero distributed specs — regardless of which
        // shard served which session.
        for i in 0..k {
            let sid = (i + 1) as u32;
            assert!(engine.shard_of(sid) < shards_n);
            assert_eq!(
                engine.lifecycle(sid),
                Some(Lifecycle::Closed),
                "study {i} at {shards_n} shards"
            );
        }
        assert!(
            engine.worker_live_sessions().iter().all(|&n| n == 0),
            "worker state leaked at {shards_n} shards"
        );
        assert_eq!(engine.live_specs(), 0);
        engine.shutdown().unwrap();
    }
}
