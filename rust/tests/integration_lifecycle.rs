//! Control-plane lifecycle and leak-detection gates.
//!
//! The acknowledged-close protocol makes worker-state leaks PROVABLE:
//! a session's handle resolves only after every institution and center
//! has freed its per-session state and said so with a `CloseAck`. This
//! suite gates:
//!
//! * the leak invariant — after K submitted/closed sessions, every
//!   worker gauge reads zero and no spec remains distributed;
//! * the traffic invariant under auto-retire —
//!   `Σ live per-session + retired == global` while old completions
//!   fold into the retired aggregate without any manual call;
//! * admission-queue semantics — deadlines reject, priority lanes
//!   order admissions, the cap holds.

use privlr::config::ExperimentConfig;
use privlr::data::synthetic;
use privlr::engine::{
    EngineOptions, Lifecycle, Priority, StudyEngine, SubmitError, SubmitOptions, SubmitPolicy,
};
use std::time::Duration;

fn cfg_3c() -> ExperimentConfig {
    ExperimentConfig {
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        ..ExperimentConfig::default()
    }
}

/// The leak gate: submit K sessions across all lanes, close them all,
/// and PROVE the workers hold zero per-session state afterwards —
/// `CloseAck` is sent only after the state is dropped, and `join`
/// returns only after the last ack, so these reads are not racy.
#[test]
fn workers_hold_zero_state_after_close_acks() {
    let ds = synthetic("t", 500, 4, 2, 0.0, 1.0, 901);
    let cfg = cfg_3c();
    let engine = StudyEngine::new(2, 3).unwrap();
    let shards = privlr::session::ShardData::split(&ds);
    let lanes = [
        Priority::Interactive,
        Priority::Batch,
        Priority::Bulk,
        Priority::Batch,
        Priority::Interactive,
        Priority::Bulk,
    ];
    let handles: Vec<_> = lanes
        .iter()
        .map(|&priority| {
            engine
                .submit_shared(&cfg, shards.clone(), SubmitOptions::with_priority(priority))
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Zero per-session state on every worker (centers AND institutions).
    let live = engine.worker_live_sessions();
    assert_eq!(live.len(), 3 + 2, "one gauge per worker");
    assert!(
        live.iter().all(|&n| n == 0),
        "worker state leaked after CloseAck: {live:?}"
    );
    // No spec remains distributed.
    assert_eq!(engine.live_specs(), 0, "session specs leaked");
    // Every session reached the Closed terminal state.
    for sid in 1..=lanes.len() as u32 {
        assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Closed), "session {sid}");
    }
    assert_eq!(engine.lifecycle_count(Lifecycle::Closed), lanes.len());
    engine.shutdown().unwrap();
}

/// The traffic invariant under the auto-retire policy: with
/// `auto_retire = N`, only the last N completions stay live in the
/// per-session map, everything older folds into the retired aggregate
/// automatically, and `Σ live + retired == global` holds at every
/// observation point.
#[test]
fn auto_retire_preserves_traffic_invariant() {
    let ds = synthetic("t", 400, 3, 2, 0.0, 1.0, 902);
    let cfg = cfg_3c();
    let keep = 3usize;
    let total = 8usize;
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 2, auto_retire: keep, ..Default::default() },
    )
    .unwrap();
    let shards = privlr::session::ShardData::split(&ds);
    let handles: Vec<_> = (0..total)
        .map(|_| {
            engine
                .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
        // Mid-run: the invariant holds at every completion.
        let snap = engine.traffic();
        let live: u64 = snap.per_session.iter().map(|&(_, b)| b).sum();
        assert_eq!(live + snap.retired_bytes, snap.total_bytes, "mid-run invariant");
    }
    let snap = engine.traffic();
    assert_eq!(
        snap.retired_sessions,
        (total - keep) as u64,
        "keep-last-{keep} over {total} completions"
    );
    assert_eq!(snap.per_session.len(), keep, "live attribution bounded by the window");
    // Retired sessions also leave the lifecycle board; the window stays.
    assert_eq!(engine.lifecycle(1), None);
    assert_eq!(
        engine.lifecycle(total as u32),
        Some(Lifecycle::Closed),
        "window sessions keep their terminal state"
    );
    // Workers are clean regardless of retirement bookkeeping.
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    let final_snap = engine.shutdown().unwrap();
    let live: u64 = final_snap.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(live + final_snap.retired_bytes, final_snap.total_bytes);
}

/// Aborted sessions drain through the same acknowledged teardown as
/// closed ones: the failure reaches the handle only after every worker
/// acked, so the leak invariant covers the failure path too.
#[test]
fn aborted_sessions_leave_zero_worker_state() {
    let ds = synthetic("t", 300, 3, 2, 0.0, 1.0, 903);
    let cfg = cfg_3c();
    let engine = StudyEngine::new(2, 3).unwrap();
    // A singular system (all-zero column, λ=0) fails in the Newton
    // solve mid-protocol — workers already hold state by then.
    let mut bad = ds.clone();
    for i in 0..bad.x.rows {
        bad.x[(i, 2)] = 0.0;
    }
    let bad_cfg = ExperimentConfig { lambda: 0.0, ..cfg.clone() };
    let h = engine.submit(&bad_cfg, &bad, SubmitOptions::interactive()).unwrap();
    let sid = h.session_id();
    assert!(h.join().is_err());
    assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Aborted));
    assert!(
        engine.worker_live_sessions().iter().all(|&n| n == 0),
        "abort path leaked worker state"
    );
    assert_eq!(engine.live_specs(), 0);
    // A healthy study afterwards is unaffected.
    let fit = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap().join().unwrap();
    assert!(fit.metrics.iterations > 1);
    engine.shutdown().unwrap();
}

/// Bounded-lane backpressure, Reject policy: with the admission slot
/// held by a long-running study and the bulk lane at capacity, a
/// `Reject`-policy submission fails deterministically with
/// `SubmitError::LaneFull`, leaves no trace (no lifecycle entry, no
/// spec, no worker contact), and the queued/running studies are
/// untouched.
#[test]
fn reject_policy_fails_fast_when_lane_is_full() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 910);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 911);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, lane_capacity: 1, ..Default::default() },
    )
    .unwrap();
    // Slot holder: admitted immediately, lane empties again.
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    // Fills the single bulk-lane slot while the cap is saturated.
    let h_queued = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    // Lane full → Reject errors synchronously, typed and downcastable.
    let err = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk().policy(SubmitPolicy::Reject),
        )
        .unwrap_err();
    match err.downcast_ref::<SubmitError>() {
        Some(SubmitError::LaneFull { priority, capacity, shard }) => {
            assert_eq!(*priority, Priority::Bulk);
            assert_eq!(*capacity, 1);
            assert_eq!(*shard, 0);
        }
        other => panic!("expected LaneFull, got {other:?} ({err:#})"),
    }
    // The rejected submission burned a session id but left no state.
    assert_eq!(engine.lifecycle(3), None, "rejected study must leave no entry");
    assert_eq!(engine.lane_depth(0, Priority::Bulk), 1, "queue untouched");
    // A different lane still has room: same policy, no error.
    let h_other = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::interactive().policy(SubmitPolicy::Reject),
        )
        .unwrap();
    h_heavy.join().unwrap();
    h_queued.join().unwrap();
    h_other.join().unwrap();
    assert_eq!(engine.peak_in_flight(), 1);
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    assert_eq!(engine.live_specs(), 0);
    engine.shutdown().unwrap();
}

/// Bounded-lane backpressure, Block policy: a submission into a full
/// lane parks the submitting thread until the driver drains the lane,
/// then queues and completes normally — backpressure delays work, it
/// never drops or corrupts it.
#[test]
fn block_policy_waits_for_lane_space_and_completes() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 912);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 913);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, lane_capacity: 1, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    let h_queued = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    // This call blocks until the queued bulk study is admitted (which
    // needs the heavy study to fully close first) — and then succeeds.
    let h_blocked = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk().policy(SubmitPolicy::Block),
        )
        .unwrap();
    // By the time submit returned, lane space had freed: the earlier
    // bulk study is no longer queued.
    assert!(engine.lane_depth(0, Priority::Bulk) <= 1);
    let fit_heavy = h_heavy.join().unwrap();
    let fit_queued = h_queued.join().unwrap();
    let fit_blocked = h_blocked.join().unwrap();
    assert!(fit_heavy.metrics.iterations > 1);
    assert_eq!(fit_queued.beta, fit_blocked.beta, "backpressure must not move numerics");
    // The blocked study's queue wait is measured from ITS submit call
    // (which happened while blocked), and is visible in its metrics.
    assert!(fit_blocked.metrics.queue_secs >= 0.0);
    assert_eq!(engine.peak_in_flight(), 1, "cap held throughout");
    assert_eq!(engine.admission_order(), vec![1, 2, 3]);
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    engine.shutdown().unwrap();
}

/// Bounded-lane backpressure, ShedOldestBulk policy: a bulk submission
/// into a full bulk lane evicts the OLDEST queued bulk study (whose
/// handle resolves with `SubmitError::Shed`), keeps the newest, and
/// never touches non-bulk lanes (which fall back to Reject).
#[test]
fn shed_policy_evicts_oldest_bulk_and_keeps_newest() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 914);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 915);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, lane_capacity: 1, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    let h_old = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    let old_session = h_old.session_id();
    // Newest-wins: the shed submission takes the queued slot.
    let h_new = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk().policy(SubmitPolicy::ShedOldestBulk),
        )
        .unwrap();
    // The evicted study's handle resolves with the typed shed error.
    let err = h_old.join().unwrap_err();
    match err.downcast_ref::<SubmitError>() {
        Some(SubmitError::Shed { session }) => assert_eq!(*session, old_session),
        other => panic!("expected Shed, got {other:?} ({err:#})"),
    }
    assert_eq!(engine.lifecycle(old_session), Some(Lifecycle::Aborted));
    // An interactive submission under the shed policy never sheds —
    // its full lane falls back to the LaneFull rejection instead.
    let h_inter = engine
        .submit(&light_cfg, &ds_light, SubmitOptions::interactive())
        .unwrap();
    let err = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::interactive().policy(SubmitPolicy::ShedOldestBulk),
        )
        .unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<SubmitError>(),
            Some(SubmitError::LaneFull { priority: Priority::Interactive, .. })
        ),
        "non-bulk lanes must not shed: {err:#}"
    );
    h_heavy.join().unwrap();
    h_inter.join().unwrap();
    let fit_new = h_new.join().unwrap();
    assert!(fit_new.metrics.iterations > 1, "the surviving bulk study runs");
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    assert_eq!(engine.live_specs(), 0);
    engine.shutdown().unwrap();
}

/// Deadlines keep expiring while a study is queued behind a full
/// admission cap: the driver's sweep rejects it at round granularity
/// (the running study's frames keep the sweep live), the rejection
/// frees lane space, and a subsequent Reject-policy submission
/// succeeds — deadline expiry IS backpressure relief.
#[test]
fn deadlines_expire_while_queued_at_capacity() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 916);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 917);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, lane_capacity: 1, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    // Wait for the driver to pop the heavy study into admission, so
    // the zero-deadline submission below deterministically takes the
    // empty lane slot (instead of racing the Block path on a full
    // lane, which would surface the deadline at submit time).
    while engine.lane_depth(0, Priority::Bulk) > 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    // Queued into the single bulk slot with an already-lapsed deadline:
    // the sweep must reject it while the heavy study still runs.
    let h_late = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk().deadline(Duration::ZERO),
        )
        .unwrap();
    let late_session = h_late.session_id();
    let err = h_late.join().unwrap_err();
    assert!(err.to_string().contains("deadline"), "got: {err:#}");
    assert_eq!(engine.lifecycle(late_session), Some(Lifecycle::Aborted));
    // The rejection freed the lane: a fail-fast submission now fits.
    let h_next = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk().policy(SubmitPolicy::Reject),
        )
        .unwrap();
    h_heavy.join().unwrap();
    h_next.join().unwrap();
    // A deadline can also cut a BLOCKED submission loose: with the
    // lane full again... (lane is empty now, so refill it first).
    let h_hold = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    let h_fill = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    let err = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::bulk()
                .policy(SubmitPolicy::Block)
                .deadline(Duration::from_millis(40)),
        )
        .unwrap_err();
    assert!(
        err.to_string().contains("deadline"),
        "blocked submit must stop waiting at its deadline: {err:#}"
    );
    h_hold.join().unwrap();
    h_fill.join().unwrap();
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    assert_eq!(engine.live_specs(), 0);
    engine.shutdown().unwrap();
}

/// Admission control: with a cap of 1, a long-running study holds the
/// only slot; queued studies are admitted strictly by lane priority
/// when slots free, and an expired deadline rejects a queued study
/// without it ever touching a worker.
#[test]
fn admission_respects_priority_lanes_cap_and_deadlines() {
    // A heavyweight first study (full mode, plenty of rows) keeps the
    // single slot busy long enough for the later submissions to queue.
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 904);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 905);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    // Submitted while the slot is held: a bulk study, then an
    // interactive one — the interactive lane must be admitted first
    // even though it arrived later.
    let h_bulk = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    let h_inter = engine
        .submit(&light_cfg, &ds_light, SubmitOptions::interactive())
        .unwrap();
    // And one with an already-lapsed deadline: rejected at its
    // admission turn, deterministically.
    let h_late = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::batch().deadline(Duration::ZERO),
        )
        .unwrap();
    let (sid_heavy, sid_bulk, sid_inter, sid_late) = (
        h_heavy.session_id(),
        h_bulk.session_id(),
        h_inter.session_id(),
        h_late.session_id(),
    );
    let err = h_late.join().unwrap_err();
    assert!(err.to_string().contains("deadline"), "got: {err:#}");
    assert_eq!(engine.lifecycle(sid_late), Some(Lifecycle::Aborted));

    h_heavy.join().unwrap();
    h_bulk.join().unwrap();
    h_inter.join().unwrap();
    assert_eq!(engine.peak_in_flight(), 1, "cap must hold");
    // Admission order: heavy first (only ready study), then the
    // interactive latecomer ahead of the earlier bulk submission.
    assert_eq!(engine.admission_order(), vec![sid_heavy, sid_inter, sid_bulk]);
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    engine.shutdown().unwrap();
}
