//! Control-plane lifecycle and leak-detection gates.
//!
//! The acknowledged-close protocol makes worker-state leaks PROVABLE:
//! a session's handle resolves only after every institution and center
//! has freed its per-session state and said so with a `CloseAck`. This
//! suite gates:
//!
//! * the leak invariant — after K submitted/closed sessions, every
//!   worker gauge reads zero and no spec remains distributed;
//! * the traffic invariant under auto-retire —
//!   `Σ live per-session + retired == global` while old completions
//!   fold into the retired aggregate without any manual call;
//! * admission-queue semantics — deadlines reject, priority lanes
//!   order admissions, the cap holds.

use privlr::config::ExperimentConfig;
use privlr::data::synthetic;
use privlr::engine::{
    EngineOptions, Lifecycle, Priority, StudyEngine, SubmitOptions,
};
use std::time::Duration;

fn cfg_3c() -> ExperimentConfig {
    ExperimentConfig {
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        ..ExperimentConfig::default()
    }
}

/// The leak gate: submit K sessions across all lanes, close them all,
/// and PROVE the workers hold zero per-session state afterwards —
/// `CloseAck` is sent only after the state is dropped, and `join`
/// returns only after the last ack, so these reads are not racy.
#[test]
fn workers_hold_zero_state_after_close_acks() {
    let ds = synthetic("t", 500, 4, 2, 0.0, 1.0, 901);
    let cfg = cfg_3c();
    let engine = StudyEngine::new(2, 3).unwrap();
    let shards = privlr::session::ShardData::split(&ds);
    let lanes = [
        Priority::Interactive,
        Priority::Batch,
        Priority::Bulk,
        Priority::Batch,
        Priority::Interactive,
        Priority::Bulk,
    ];
    let handles: Vec<_> = lanes
        .iter()
        .map(|&priority| {
            engine
                .submit_shared(&cfg, shards.clone(), SubmitOptions::with_priority(priority))
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Zero per-session state on every worker (centers AND institutions).
    let live = engine.worker_live_sessions();
    assert_eq!(live.len(), 3 + 2, "one gauge per worker");
    assert!(
        live.iter().all(|&n| n == 0),
        "worker state leaked after CloseAck: {live:?}"
    );
    // No spec remains distributed.
    assert_eq!(engine.live_specs(), 0, "session specs leaked");
    // Every session reached the Closed terminal state.
    for sid in 1..=lanes.len() as u32 {
        assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Closed), "session {sid}");
    }
    assert_eq!(engine.lifecycle_count(Lifecycle::Closed), lanes.len());
    engine.shutdown().unwrap();
}

/// The traffic invariant under the auto-retire policy: with
/// `auto_retire = N`, only the last N completions stay live in the
/// per-session map, everything older folds into the retired aggregate
/// automatically, and `Σ live + retired == global` holds at every
/// observation point.
#[test]
fn auto_retire_preserves_traffic_invariant() {
    let ds = synthetic("t", 400, 3, 2, 0.0, 1.0, 902);
    let cfg = cfg_3c();
    let keep = 3usize;
    let total = 8usize;
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 2, auto_retire: keep },
    )
    .unwrap();
    let shards = privlr::session::ShardData::split(&ds);
    let handles: Vec<_> = (0..total)
        .map(|_| {
            engine
                .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
        // Mid-run: the invariant holds at every completion.
        let snap = engine.traffic();
        let live: u64 = snap.per_session.iter().map(|&(_, b)| b).sum();
        assert_eq!(live + snap.retired_bytes, snap.total_bytes, "mid-run invariant");
    }
    let snap = engine.traffic();
    assert_eq!(
        snap.retired_sessions,
        (total - keep) as u64,
        "keep-last-{keep} over {total} completions"
    );
    assert_eq!(snap.per_session.len(), keep, "live attribution bounded by the window");
    // Retired sessions also leave the lifecycle board; the window stays.
    assert_eq!(engine.lifecycle(1), None);
    assert_eq!(
        engine.lifecycle(total as u32),
        Some(Lifecycle::Closed),
        "window sessions keep their terminal state"
    );
    // Workers are clean regardless of retirement bookkeeping.
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    let final_snap = engine.shutdown().unwrap();
    let live: u64 = final_snap.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(live + final_snap.retired_bytes, final_snap.total_bytes);
}

/// Aborted sessions drain through the same acknowledged teardown as
/// closed ones: the failure reaches the handle only after every worker
/// acked, so the leak invariant covers the failure path too.
#[test]
fn aborted_sessions_leave_zero_worker_state() {
    let ds = synthetic("t", 300, 3, 2, 0.0, 1.0, 903);
    let cfg = cfg_3c();
    let engine = StudyEngine::new(2, 3).unwrap();
    // A singular system (all-zero column, λ=0) fails in the Newton
    // solve mid-protocol — workers already hold state by then.
    let mut bad = ds.clone();
    for i in 0..bad.x.rows {
        bad.x[(i, 2)] = 0.0;
    }
    let bad_cfg = ExperimentConfig { lambda: 0.0, ..cfg.clone() };
    let h = engine.submit(&bad_cfg, &bad, SubmitOptions::interactive()).unwrap();
    let sid = h.session_id();
    assert!(h.join().is_err());
    assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Aborted));
    assert!(
        engine.worker_live_sessions().iter().all(|&n| n == 0),
        "abort path leaked worker state"
    );
    assert_eq!(engine.live_specs(), 0);
    // A healthy study afterwards is unaffected.
    let fit = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap().join().unwrap();
    assert!(fit.metrics.iterations > 1);
    engine.shutdown().unwrap();
}

/// Admission control: with a cap of 1, a long-running study holds the
/// only slot; queued studies are admitted strictly by lane priority
/// when slots free, and an expired deadline rejects a queued study
/// without it ever touching a worker.
#[test]
fn admission_respects_priority_lanes_cap_and_deadlines() {
    // A heavyweight first study (full mode, plenty of rows) keeps the
    // single slot busy long enough for the later submissions to queue.
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 904);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 905);
    let heavy_cfg = ExperimentConfig {
        mode: privlr::config::SecurityMode::Full,
        ..cfg_3c()
    };
    let light_cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, auto_retire: 0 },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg, &ds_heavy, SubmitOptions::bulk()).unwrap();
    // Submitted while the slot is held: a bulk study, then an
    // interactive one — the interactive lane must be admitted first
    // even though it arrived later.
    let h_bulk = engine.submit(&light_cfg, &ds_light, SubmitOptions::bulk()).unwrap();
    let h_inter = engine
        .submit(&light_cfg, &ds_light, SubmitOptions::interactive())
        .unwrap();
    // And one with an already-lapsed deadline: rejected at its
    // admission turn, deterministically.
    let h_late = engine
        .submit(
            &light_cfg,
            &ds_light,
            SubmitOptions::batch().deadline(Duration::ZERO),
        )
        .unwrap();
    let (sid_heavy, sid_bulk, sid_inter, sid_late) = (
        h_heavy.session_id(),
        h_bulk.session_id(),
        h_inter.session_id(),
        h_late.session_id(),
    );
    let err = h_late.join().unwrap_err();
    assert!(err.to_string().contains("deadline"), "got: {err:#}");
    assert_eq!(engine.lifecycle(sid_late), Some(Lifecycle::Aborted));

    h_heavy.join().unwrap();
    h_bulk.join().unwrap();
    h_inter.join().unwrap();
    assert_eq!(engine.peak_in_flight(), 1, "cap must hold");
    // Admission order: heavy first (only ready study), then the
    // interactive latecomer ahead of the earlier bulk submission.
    assert_eq!(engine.admission_order(), vec![sid_heavy, sid_inter, sid_bulk]);
    assert!(engine.worker_live_sessions().iter().all(|&n| n == 0));
    engine.shutdown().unwrap();
}
