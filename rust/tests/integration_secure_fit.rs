//! End-to-end integration of the secure protocol across topologies,
//! security modes, regularization strengths and datasets — all
//! checked against the centralized gold standard (the paper's Fig 2
//! exactness claim, R² = 1.00).

use privlr::baseline::centralized_fit;
use privlr::config::{ExperimentConfig, SecurityMode};
use privlr::coordinator::secure_fit;
use privlr::data::{insurance_like, parkinsons_like, synthetic, ParkinsonsTarget};
use privlr::util::stats::{max_abs_diff, r_squared};

fn assert_matches_gold(ds: &privlr::data::Dataset, cfg: &ExperimentConfig, tol: f64) {
    let secure = secure_fit(ds, cfg).expect("secure fit");
    let gold = centralized_fit(ds, cfg.lambda, cfg.tol, cfg.max_iters).expect("gold");
    let r2 = r_squared(&secure.beta, &gold.beta);
    let md = max_abs_diff(&secure.beta, &gold.beta);
    assert!(r2 > 0.999_999, "{}: R² = {r2}", ds.name);
    assert!(md < tol, "{}: max|Δβ| = {md}", ds.name);
}

#[test]
fn topology_sweep_matches_gold() {
    let ds = synthetic("t", 3_000, 5, 4, 0.0, 1.0, 101);
    for (w, t) in [(1usize, 1usize), (3, 2), (5, 3), (7, 7), (9, 2)] {
        let cfg = ExperimentConfig {
            num_centers: w,
            threshold: t,
            max_iters: 40,
            ..Default::default()
        };
        assert_matches_gold(&ds, &cfg, 1e-5);
    }
}

#[test]
fn institutions_sweep_matches_gold() {
    for s in [1usize, 2, 7, 16] {
        let ds = synthetic("t", 2_400, 4, s, 0.0, 1.0, 102);
        let cfg = ExperimentConfig {
            max_iters: 40,
            ..Default::default()
        };
        assert_matches_gold(&ds, &cfg, 1e-5);
    }
}

#[test]
fn lambda_sweep_matches_gold() {
    let ds = synthetic("t", 2_000, 6, 5, 0.0, 1.0, 103);
    for lambda in [0.0, 0.01, 1.0, 50.0] {
        let cfg = ExperimentConfig {
            lambda,
            max_iters: 60,
            ..Default::default()
        };
        assert_matches_gold(&ds, &cfg, 1e-4);
    }
}

#[test]
fn both_security_modes_agree_with_each_other() {
    let ds = synthetic("t", 1_500, 8, 5, 0.0, 1.0, 104);
    let mut betas = Vec::new();
    for mode in [SecurityMode::Pragmatic, SecurityMode::Full] {
        let cfg = ExperimentConfig {
            mode,
            max_iters: 40,
            ..Default::default()
        };
        betas.push(secure_fit(&ds, &cfg).unwrap().beta);
    }
    assert!(max_abs_diff(&betas[0], &betas[1]) < 1e-6);
}

#[test]
fn paper_workload_insurance_shape() {
    // The ill-conditioned wide workload: integer codes, rare positives.
    let ds = insurance_like(42);
    let cfg = ExperimentConfig {
        max_iters: 50,
        ..Default::default()
    };
    let fit = secure_fit(&ds, &cfg).unwrap();
    // paper: 8 iterations on Insurance
    assert!(
        (5..=12).contains(&(fit.metrics.iterations as usize)),
        "iterations {}",
        fit.metrics.iterations
    );
    assert_matches_gold(&ds, &cfg, 1e-4);
}

#[test]
fn paper_workload_parkinsons_pair() {
    let cfg = ExperimentConfig {
        max_iters: 50,
        ..Default::default()
    };
    let motor = parkinsons_like(ParkinsonsTarget::Motor, 42);
    let total = parkinsons_like(ParkinsonsTarget::Total, 42);
    let fm = secure_fit(&motor, &cfg).unwrap();
    let ft = secure_fit(&total, &cfg).unwrap();
    // paper: 6 iterations each, traces nearly overlap
    assert!((4..=10).contains(&(fm.metrics.iterations as usize)));
    assert!((4..=10).contains(&(ft.metrics.iterations as usize)));
    assert_matches_gold(&motor, &cfg, 1e-5);
    assert_matches_gold(&total, &cfg, 1e-5);
}

#[test]
fn traffic_grows_linearly_with_centers() {
    // Submission traffic ∝ w (one share vector per center).
    let ds = synthetic("t", 1_000, 6, 4, 0.0, 1.0, 105);
    let run = |w: usize, t: usize| {
        let cfg = ExperimentConfig {
            num_centers: w,
            threshold: t,
            max_iters: 40,
            ..Default::default()
        };
        let fit = secure_fit(&ds, &cfg).unwrap();
        (
            fit.metrics.traffic.submission_bytes as f64 / fit.metrics.iterations as f64,
            fit.metrics.iterations,
        )
    };
    let (b3, _) = run(3, 2);
    let (b6, _) = run(6, 2);
    let ratio = b6 / b3;
    assert!(
        (1.5..=2.5).contains(&ratio),
        "submission traffic should ~double from w=3 to w=6, got {ratio}"
    );
}

#[test]
fn full_mode_traffic_exceeds_pragmatic() {
    let ds = synthetic("t", 1_000, 10, 4, 0.0, 1.0, 106);
    let run = |mode: SecurityMode| {
        let cfg = ExperimentConfig {
            mode,
            max_iters: 40,
            ..Default::default()
        };
        secure_fit(&ds, &cfg).unwrap().metrics.traffic.total_bytes
    };
    let prag = run(SecurityMode::Pragmatic);
    let full = run(SecurityMode::Full);
    assert!(
        full > prag,
        "sharing the Hessian to all centers must cost more: {full} vs {prag}"
    );
}

#[test]
fn invalid_configurations_error_cleanly() {
    let ds = synthetic("t", 100, 3, 2, 0.0, 1.0, 107);
    let bad = ExperimentConfig {
        threshold: 10,
        num_centers: 3,
        ..Default::default()
    };
    assert!(secure_fit(&ds, &bad).is_err());
    let bad_tol = ExperimentConfig {
        tol: -1.0,
        ..Default::default()
    };
    assert!(secure_fit(&ds, &bad_tol).is_err());
}

#[test]
fn deterministic_given_seed() {
    // Share randomness and data are seed-deterministic, field-domain
    // aggregation is exact and order-independent, and the one
    // order-sensitive f64 fold — the pragmatic-mode plaintext Hessian —
    // is summed in institution-id order at the lead center regardless
    // of arrival order. Runs are therefore BIT-identical, which is the
    // same invariant the session engine's concurrent-equals-sequential
    // guarantee rests on.
    let ds = synthetic("t", 800, 5, 3, 0.0, 1.0, 108);
    let cfg = ExperimentConfig {
        seed: 9,
        max_iters: 40,
        ..Default::default()
    };
    let a = secure_fit(&ds, &cfg).unwrap();
    let b = secure_fit(&ds, &cfg).unwrap();
    assert_eq!(a.beta, b.beta, "bit-identical β");
    assert_eq!(a.metrics.deviance_trace, b.metrics.deviance_trace);
    assert_eq!(a.metrics.iterations, b.metrics.iterations);
}
