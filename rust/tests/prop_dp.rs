//! Acceptance gates for the DP release layer ([`privlr::dp`]):
//!
//! * the institution-side noise path is **replay-stable and bitwise
//!   deterministic**: the same secret per-session nonce produces the
//!   same partial noise vector and the same share frames across
//!   `kernel_threads ∈ {1, 2, 4}` and ISA scalar/auto — a duplicated
//!   or re-sent noise frame is indistinguishable from the original —
//!   while the nonce itself is NOT derivable from the shared config
//!   (two specs built from identical config draw distinct nonces);
//! * the value stream and the share-coefficient stream are domain
//!   separated — re-keying one never perturbs the other;
//! * center-side folds of partial-noise shares are **field-exact**:
//!   any t-quorum reconstructs exactly Σⱼ encode(ηⱼ), no drift;
//! * summed partials follow the calibrated mechanism's law (Gaussian
//!   σ, Laplace 2b² variance), checked empirically;
//! * the [`privlr::dp::DpAccountant`] is monotone, order-invariant,
//!   and exhausts **exactly** at the composed budget bound — with
//!   refunds restoring capacity;
//! * after warm-up, one full institution-side noise round (sample +
//!   encode + share) performs **zero heap allocations** — measured
//!   with a counting global allocator, for both mechanisms.

use privlr::config::KernelIsa;
use privlr::dp::{
    sample_partial_noise, DpAccountant, DpComposition, DpConfig, DpMechanism, DpParams,
    DP_NOISE_STREAM, DP_SHARE_STREAM,
};
use privlr::field::Fp;
use privlr::fixed::FixedCodec;
use privlr::secure::{encode_share_into, encode_share_into_isa, secure_add, ShareContext, SharePool};
use privlr::shamir::{reconstruct_batch, ShamirParams};
use privlr::simd::resolve;
use privlr::util::rng::{derive_seed, ChaCha20Rng};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- thread-local allocation counter (same pattern as
// prop_secure_pipeline: counts THIS thread only, Cell has no
// destructor so TLS access cannot recurse into the allocator) --------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers ------------------------------------------------------------

fn params(mechanism: DpMechanism, s: usize) -> DpParams {
    DpParams {
        mechanism,
        epsilon: 1.0,
        delta: 1e-6,
        sensitivity: 2.0,
        num_partials: s,
        // All-honest calibration: each gate below reasons about the
        // sum of ALL S partials, so the partial scale must target
        // exactly that sum. Collusion-threshold calibration (h < S)
        // has its own unit gates in `privlr::dp`.
        num_honest: s,
        rows: 100,
    }
}

/// One institution's noise round exactly as `handle_dp_noise` runs it:
/// value stream keyed by `DP_NOISE_STREAM`, share coefficients by
/// `DP_SHARE_STREAM` — both derived from the institution's secret
/// per-session nonce (pinned here so the gates are deterministic) —
/// summary layout `[η | 0.0]`.
fn noise_round(
    p: &DpParams,
    d: usize,
    nonce: u64,
    threads: usize,
    isa: privlr::simd::Isa,
    ctx: &ShareContext,
    codec: &FixedCodec,
    summary: &mut [f64],
    pool: &mut SharePool,
) {
    let mut rng = ChaCha20Rng::seed_from_u64(derive_seed(nonce, DP_NOISE_STREAM));
    sample_partial_noise(p, d, &mut rng, &mut summary[..d]);
    summary[d] = 0.0;
    encode_share_into_isa(
        ctx,
        codec,
        summary,
        derive_seed(nonce, DP_SHARE_STREAM),
        threads,
        isa,
        pool,
    )
    .unwrap();
}

/// Gate 1: replay stability and thread/ISA invariance. A crash-replayed
/// or fault-duplicated noise frame must be BIT-identical to the
/// original, regardless of the worker's thread pool or ISA — otherwise
/// deduplication by `(iter, institution)` would not be sound.
#[test]
fn noise_round_bit_identical_across_threads_and_isa() {
    let d = 37usize; // straddles SIMD lanes
    let scheme = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(scheme);
    let codec = FixedCodec::default();
    let auto = resolve(KernelIsa::Auto);
    let scalar = resolve(KernelIsa::Scalar);
    for mech in [DpMechanism::Gaussian, DpMechanism::Laplace] {
        let p = params(mech, 3);
        for nonce in [1u64, 0xDEAD_BEEF, u64::MAX - 7] {
            let mut ref_summary = vec![0.0; d + 1];
            let mut ref_pool = SharePool::new();
            noise_round(&p, d, nonce, 1, scalar, &ctx, &codec, &mut ref_summary, &mut ref_pool);
            for threads in [1usize, 2, 4] {
                for isa in [scalar, auto] {
                    let mut summary = vec![0.0; d + 1];
                    let mut pool = SharePool::new();
                    noise_round(&p, d, nonce, threads, isa, &ctx, &codec, &mut summary, &mut pool);
                    // noise values bitwise equal (compare the bits: NaN-safe
                    // and stricter than ==)
                    for (a, b) in ref_summary.iter().zip(&summary) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{mech:?} nonce={nonce}");
                    }
                    for holder in 0..5 {
                        assert_eq!(
                            ref_pool.holder(holder),
                            pool.holder(holder),
                            "{mech:?} nonce={nonce} threads={threads} isa={isa:?} holder={holder}"
                        );
                    }
                }
            }
        }
    }
}

/// Gate 1b: the value stream and the share-coefficient stream are
/// domain separated — distinct derived seeds, and distinct noise
/// across institutions of the same session.
#[test]
fn noise_and_share_streams_are_domain_separated() {
    assert_ne!(DP_NOISE_STREAM, DP_SHARE_STREAM);
    for share_seed in [0u64, 1, 42, u64::MAX] {
        assert_ne!(
            derive_seed(share_seed, DP_NOISE_STREAM),
            derive_seed(share_seed, DP_SHARE_STREAM),
            "seed {share_seed}"
        );
    }
    // different institutions (different nonces) draw different noise
    let p = params(DpMechanism::Gaussian, 2);
    let mut a = vec![0.0; 8];
    let mut b = vec![0.0; 8];
    let mut rng_a = ChaCha20Rng::seed_from_u64(derive_seed(11, DP_NOISE_STREAM));
    let mut rng_b = ChaCha20Rng::seed_from_u64(derive_seed(12, DP_NOISE_STREAM));
    sample_partial_noise(&p, 8, &mut rng_a, &mut a);
    sample_partial_noise(&p, 8, &mut rng_b, &mut b);
    assert_ne!(a, b);
}

/// Gate 1c: the noise nonce is stable within a spec (so crash replay
/// reproduces byte-identical frames) but NOT a function of the shared
/// config — two specs constructed from IDENTICAL (session, shards,
/// scheme, seed) draw distinct nonces, so no participant can recompute
/// another institution's noise stream from the config it already
/// knows. This is the property that closes the noise-stripping attack.
#[test]
fn dp_nonce_is_not_derivable_from_the_shared_config() {
    use privlr::linalg::Matrix;
    use privlr::session::{SessionSpec, ShardData};
    use std::sync::Arc;
    let shard = || Arc::new(ShardData { x: Matrix::zeros(4, 2), y: vec![0.0; 4] });
    let make = || {
        SessionSpec::new(
            7,
            vec![shard(), shard()],
            ShamirParams::new(2, 3).unwrap(),
            FixedCodec::default(),
            false,
            1,
            resolve(KernelIsa::Scalar),
            424242,
        )
    };
    let a = make();
    let b = make();
    for j in 0..2u16 {
        let n_a = a.dp_noise_seed(j).unwrap();
        assert_eq!(
            n_a,
            a.dp_noise_seed(j).unwrap(),
            "replay within a spec must be stable"
        );
        assert_ne!(
            n_a,
            b.dp_noise_seed(j).unwrap(),
            "twin specs from identical config must not share institution {j}'s nonce"
        );
    }
    // Out-of-topology institutions are refused, not silently seeded.
    assert!(a.dp_noise_seed(9).is_err());
}

/// Gate 2: center-side folds of partial-noise shares are field-exact.
/// For every t-quorum, reconstructing the folded accumulators yields
/// EXACTLY Σⱼ encode(ηⱼ) in 𝔽ₚ — share arithmetic adds no error on
/// top of the one fixed-point quantization per institution.
#[test]
fn folded_noise_shares_reconstruct_to_exact_field_sum() {
    let d = 19usize;
    let s = 4usize; // institutions
    let scheme = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(scheme);
    let codec = FixedCodec::default();
    for mech in [DpMechanism::Gaussian, DpMechanism::Laplace] {
        let p = params(mech, s);
        let mut accs: Vec<Vec<Fp>> = (0..5).map(|_| vec![Fp::ZERO; d + 1]).collect();
        let mut expect = vec![Fp::ZERO; d + 1];
        let mut pool = SharePool::new();
        for j in 0..s as u64 {
            let mut summary = vec![0.0; d + 1];
            let mut rng = ChaCha20Rng::seed_from_u64(derive_seed(100 + j, DP_NOISE_STREAM));
            sample_partial_noise(&p, d, &mut rng, &mut summary[..d]);
            summary[d] = 0.0;
            let enc = codec.encode_slice(&summary).unwrap();
            secure_add(&mut expect, &enc);
            encode_share_into(
                &ctx,
                &codec,
                &summary,
                derive_seed(100 + j, DP_SHARE_STREAM),
                1,
                &mut pool,
            )
            .unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                secure_add(acc, pool.holder(c));
            }
        }
        for quorum_idx in [[0usize, 1, 2], [2, 3, 4], [0, 2, 4]] {
            let quorum: Vec<(usize, &[Fp])> = quorum_idx
                .iter()
                .map(|&c| (c, accs[c].as_slice()))
                .collect();
            let rec = reconstruct_batch(scheme, &quorum).unwrap();
            assert_eq!(rec, expect, "{mech:?} quorum {quorum_idx:?}");
            // the deviance slot carried η = 0 from every institution
            assert_eq!(rec[d], Fp::ZERO);
        }
    }
}

/// Gate 3: summed partials follow the calibrated mechanism's law. S
/// institutions' Gaussian partials sum to N(0, σ²); gamma-difference
/// partials sum to Laplace(b) with variance 2b². Empirical moments
/// over many independent streams.
#[test]
fn summed_partials_match_mechanism_law() {
    let s = 3usize;
    let trials = 20_000usize;

    let gp = params(DpMechanism::Gaussian, s);
    let sigma = gp.gaussian_sigma();
    let mut sum = 0.0;
    let mut sum_sq = 0.0;
    for trial in 0..trials {
        let mut total = 0.0;
        for j in 0..s {
            let mut rng = ChaCha20Rng::seed_from_u64(
                derive_seed((trial * s + j) as u64, DP_NOISE_STREAM),
            );
            let mut eta = [0.0];
            sample_partial_noise(&gp, 1, &mut rng, &mut eta);
            total += eta[0];
        }
        sum += total;
        sum_sq += total * total;
    }
    let mean = sum / trials as f64;
    let var = sum_sq / trials as f64 - mean * mean;
    assert!(mean.abs() < 0.05 * sigma, "gaussian mean {mean} vs σ {sigma}");
    assert!(
        (var.sqrt() - sigma).abs() < 0.05 * sigma,
        "gaussian std {} vs σ {sigma}",
        var.sqrt()
    );

    let lp = params(DpMechanism::Laplace, s);
    let b = lp.laplace_b(1);
    let mut sum = 0.0;
    let mut sum_sq = 0.0;
    for trial in 0..trials {
        let mut total = 0.0;
        for j in 0..s {
            let mut rng = ChaCha20Rng::seed_from_u64(
                derive_seed(0xBAD_0000 + (trial * s + j) as u64, DP_NOISE_STREAM),
            );
            let mut eta = [0.0];
            sample_partial_noise(&lp, 1, &mut rng, &mut eta);
            total += eta[0];
        }
        sum += total;
        sum_sq += total * total;
    }
    let mean = sum / trials as f64;
    let var = sum_sq / trials as f64 - mean * mean;
    assert!(mean.abs() < 0.1 * b, "laplace mean {mean} vs b {b}");
    assert!(
        (var - 2.0 * b * b).abs() < 0.15 * (2.0 * b * b),
        "laplace var {var} vs 2b² {}",
        2.0 * b * b
    );
}

/// Gate 4a: the accountant's composed spend is monotone in the number
/// of charges, under BOTH composition rules read from the same ledger.
#[test]
fn accountant_spend_is_monotone() {
    let acct = DpAccountant::new();
    let basic = DpConfig {
        epsilon: 0.25,
        delta: 2f64.powi(-20),
        ..DpConfig::default()
    };
    let advanced = DpConfig {
        composition: DpComposition::Advanced,
        budget_delta: 2f64.powi(-10),
        ..basic
    };
    let (mut last_b, mut last_a) = (0.0, 0.0);
    for session in 0..32u32 {
        acct.try_charge(session, &basic).unwrap();
        let (eb, _) = acct.spent(&basic);
        let (ea, da) = acct.spent(&advanced);
        assert!(eb >= last_b, "basic ε must be monotone: {eb} < {last_b}");
        assert!(ea >= last_a, "advanced ε must be monotone: {ea} < {last_a}");
        assert!(da > 0.0 && da <= advanced.budget_delta);
        last_b = eb;
        last_a = ea;
    }
    assert_eq!(acct.charges(), 32);
    // 32 × ε=0.25 in exact f64 arithmetic
    assert_eq!(last_b, 8.0);
    // advanced composition beats basic for many small charges
    assert!(last_a < last_b, "advanced {last_a} should beat basic {last_b}");
}

/// Gate 4b: composition is order-invariant — a permuted spend multiset
/// composes to the same totals (exactly for basic over dyadic spends;
/// to 1e-12 relative for advanced, whose slack terms are transcendental).
#[test]
fn accountant_composition_is_order_invariant() {
    let spends = [
        (0.5, 2f64.powi(-22)),
        (0.25, 2f64.powi(-20)),
        (1.0, 2f64.powi(-24)),
        (0.125, 2f64.powi(-21)),
        (2.0, 2f64.powi(-23)),
    ];
    let perms: [[usize; 5]; 4] = [[0, 1, 2, 3, 4], [4, 3, 2, 1, 0], [2, 0, 4, 1, 3], [1, 4, 0, 3, 2]];
    let reference_basic = DpAccountant::compose(&spends, DpComposition::Basic, 0.0);
    let reference_adv = DpAccountant::compose(&spends, DpComposition::Advanced, 2f64.powi(-10));
    for perm in perms {
        let shuffled: Vec<(f64, f64)> = perm.iter().map(|&i| spends[i]).collect();
        let b = DpAccountant::compose(&shuffled, DpComposition::Basic, 0.0);
        assert_eq!(b, reference_basic, "basic perm {perm:?}");
        let a = DpAccountant::compose(&shuffled, DpComposition::Advanced, 2f64.powi(-10));
        assert!(
            (a.0 - reference_adv.0).abs() <= 1e-12 * reference_adv.0.abs(),
            "advanced ε perm {perm:?}: {} vs {}",
            a.0,
            reference_adv.0
        );
        assert!((a.1 - reference_adv.1).abs() <= 1e-12 * reference_adv.1.abs());
    }
}

/// Gate 4c: exhaustion is EXACT. With ε budget = k·ε (dyadic, so the
/// sums are exact in f64), exactly k charges are admitted; the k+1-th
/// is refused with the would-be totals; a refund restores exactly one
/// slot. The δ axis exhausts the same way.
#[test]
fn accountant_exhausts_exactly_at_the_budget_bound() {
    // ε axis: budget 1.0 at ε=0.25 per release → exactly 4 admits.
    let acct = DpAccountant::new();
    let cfg = DpConfig {
        epsilon: 0.25,
        delta: 2f64.powi(-20),
        budget_epsilon: 1.0,
        ..DpConfig::default()
    };
    for session in 0..4u32 {
        acct.try_charge(session, &cfg)
            .unwrap_or_else(|e| panic!("charge {session} within budget refused: {e}"));
    }
    let err = acct.try_charge(99, &cfg).unwrap_err();
    assert_eq!(err.would_spend_epsilon, 1.25);
    assert_eq!(err.budget_epsilon, 1.0);
    assert_eq!(acct.charges(), 4, "refused charge must not touch the ledger");
    assert_eq!(acct.spent(&cfg), (1.0, 4.0 * 2f64.powi(-20)));
    // a refund restores exactly one admit
    acct.refund(2);
    assert_eq!(acct.charges(), 3);
    acct.try_charge(100, &cfg).unwrap();
    assert!(acct.try_charge(101, &cfg).is_err());
    // refunding an unknown session is a no-op
    acct.refund(12345);
    assert_eq!(acct.charges(), 4);

    // δ axis: budget 4·2⁻²⁰ at δ=2⁻²⁰ per release, ε unbounded.
    let acct = DpAccountant::new();
    let cfg = DpConfig {
        epsilon: 0.25,
        delta: 2f64.powi(-20),
        budget_delta: 2f64.powi(-18),
        ..DpConfig::default()
    };
    for session in 0..4u32 {
        acct.try_charge(session, &cfg).unwrap();
    }
    let err = acct.try_charge(99, &cfg).unwrap_err();
    assert_eq!(err.would_spend_delta, 5.0 * 2f64.powi(-20));
    assert_eq!(err.budget_delta, 2f64.powi(-18));
}

/// Gate 5: after warm-up, one full institution-side noise round —
/// ChaCha re-key, partial-noise draw, fused encode+share into the
/// warmed pool — allocates NOTHING, for both mechanisms. The DP
/// release round inherits the hot path's zero-allocation guarantee.
#[test]
fn warm_noise_round_is_allocation_free() {
    let d = 64usize;
    let scheme = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(scheme);
    let codec = FixedCodec::default();
    let scalar = resolve(KernelIsa::Scalar);
    for mech in [DpMechanism::Gaussian, DpMechanism::Laplace] {
        let p = params(mech, 3);
        let mut summary = vec![0.0; d + 1];
        let mut pool = SharePool::new();
        // Warm-up: grows the pool's holder buffers for this length.
        for seed in 0..3u64 {
            noise_round(&p, d, seed, 1, scalar, &ctx, &codec, &mut summary, &mut pool);
        }
        let before = allocs_here();
        for seed in 100..104u64 {
            noise_round(&p, d, seed, 1, scalar, &ctx, &codec, &mut summary, &mut pool);
        }
        let allocated = allocs_here() - before;
        assert_eq!(
            allocated, 0,
            "warm {mech:?} noise rounds must not allocate"
        );
    }
}
