//! Equivalence property tests for the blocked/batched hot-path
//! kernels introduced by the perf rework:
//!
//! * `linalg::syrk_upper_blocked` ≡ naive per-row `Matrix::syr_upper`
//!   (bit-identical on finite inputs);
//! * `model::local_stats` (blocked, single worker) ≡
//!   `model::local_stats_reference` (bit-identical), and the
//!   multithreaded fan-out ≡ reference up to f64 merge re-association,
//!   deterministically;
//! * Vandermonde `shamir::share_batch` ≡ per-secret Horner
//!   `shamir::share_batch_horner` on the same RNG stream (identical
//!   shares — field arithmetic is exact);
//! * ISA invariance: the `simd::resolve(Auto)`-dispatched f64 kernels
//!   (`syrk_upper_blocked_isa`, SIMD `Workspace`) ≡ scalar, bitwise,
//!   at lane-straddling dimensions and across kernel_threads ∈
//!   {1, 2, 4}.
//!
//! Sizes deliberately straddle the kernels' block boundaries (n and
//! batch not multiples of the tile; batch sizes 0, 1, tile±1), per the
//! regression checklist.

use privlr::config::KernelIsa;
use privlr::field::Fp;
use privlr::linalg::{syrk_upper_blocked, syrk_upper_blocked_isa, Matrix, SYRK_ROW_TILE};
use privlr::model::{self, LocalStats, Workspace};
use privlr::shamir::{
    reconstruct_batch, share_batch, share_batch_horner, share_batch_with, ShamirParams,
    VandermondeTable,
};
use privlr::simd::{resolve, Isa};
use privlr::util::rng::{ChaCha20Rng, Rng, SplitMix64};

/// Run `prop` for `cases` seeded iterations, reporting the seed on panic.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    for seed in 0..cases {
        let mut rng = SplitMix64::new(0xBEEF_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

/// Sizes that straddle a block boundary of width `tile`.
fn straddling_sizes(tile: usize, rng: &mut SplitMix64) -> Vec<usize> {
    let mut v = vec![0, 1, tile - 1, tile, tile + 1, 2 * tile + 3];
    v.push(1 + rng.next_below((3 * tile) as u64) as usize);
    v
}

fn random_shard(n: usize, d: usize, rng: &mut SplitMix64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let mut x = Matrix::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            // exact zeros exercise the reference kernel's zero-skip
            x[(i, j)] = if rng.next_bernoulli(0.15) {
                0.0
            } else {
                rng.next_gaussian()
            };
        }
        y[i] = f64::from(rng.next_bernoulli(0.4));
    }
    let beta: Vec<f64> = (0..d).map(|_| rng.next_range_f64(-2.0, 2.0)).collect();
    (x, y, beta)
}

#[test]
fn prop_syrk_blocked_equals_naive_rank1() {
    forall("syrk blocked ≡ naive", 30, |rng| {
        let d = 1 + rng.next_below(12) as usize;
        for n in straddling_sizes(SYRK_ROW_TILE, rng) {
            let mut x = Matrix::zeros(n, d);
            for v in x.data.iter_mut() {
                *v = if rng.next_bernoulli(0.1) {
                    0.0
                } else {
                    rng.next_gaussian()
                };
            }
            // weights of any sign, with exact zeros
            let w: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.next_bernoulli(0.1) {
                        0.0
                    } else {
                        rng.next_range_f64(-1.5, 1.5)
                    }
                })
                .collect();
            let mut naive = Matrix::zeros(d, d);
            for i in 0..n {
                naive.syr_upper(w[i], x.row(i));
            }
            let mut blocked = Matrix::zeros(d, d);
            let mut scratch = Vec::new();
            syrk_upper_blocked(&mut blocked, &x, &w, 0, n, &mut scratch);
            assert_eq!(blocked.data, naive.data, "n={n} d={d}");
        }
    });
}

#[test]
fn prop_local_stats_blocked_equals_reference_bitwise() {
    forall("local_stats blocked ≡ reference", 20, |rng| {
        let d = 2 + rng.next_below(8) as usize;
        for n in straddling_sizes(SYRK_ROW_TILE, rng) {
            let (x, y, beta) = random_shard(n, d, rng);
            let reference = model::local_stats_reference(&x, &y, &beta);
            let blocked = model::local_stats(&x, &y, &beta);
            assert_eq!(blocked.h.data, reference.h.data, "H: n={n} d={d}");
            assert_eq!(blocked.g, reference.g, "g: n={n} d={d}");
            assert_eq!(blocked.dev, reference.dev, "dev: n={n} d={d}");
            assert_eq!(blocked.n, reference.n);
        }
    });
}

#[test]
fn prop_local_stats_multithreaded_matches_reference() {
    forall("local_stats mt ≈ reference, deterministic", 8, |rng| {
        let d = 2 + rng.next_below(6) as usize;
        // big enough that the fan-out actually engages (≥ 4 tiles/worker)
        let n = 8 * SYRK_ROW_TILE + 1 + rng.next_below(512) as usize;
        let (x, y, beta) = random_shard(n, d, rng);
        let reference = model::local_stats_reference(&x, &y, &beta);
        for threads in [2usize, 4] {
            let mut ws = Workspace::new(d, threads);
            let mut got = LocalStats::zeros(d);
            model::local_stats_into(&mut ws, &x, &y, &beta, &mut got);
            assert!(
                got.h.max_abs_diff(&reference.h) < 1e-9,
                "threads={threads} n={n}"
            );
            for (a, b) in got.g.iter().zip(&reference.g) {
                assert!((a - b).abs() < 1e-9);
            }
            assert!((got.dev - reference.dev).abs() < 1e-8);
            // determinism: same partition, ordered merge
            let mut again = LocalStats::zeros(d);
            model::local_stats_into(&mut ws, &x, &y, &beta, &mut again);
            assert_eq!(got.h.data, again.h.data);
            assert_eq!(got.g, again.g);
            assert_eq!(got.dev, again.dev);
        }
    });
}

// ---- ISA invariance (scalar ≡ simd, bitwise) ----------------------------
//
// `resolve(Auto)` yields Simd exactly when this host can run the AVX2
// kernels; on hosts where it yields Scalar these properties compare
// the reference against itself and pass trivially — the same tests
// become the real vector-vs-scalar gate on AVX2 hardware, with no
// cfg-juggling in the suite.

#[test]
fn prop_syrk_isa_dispatch_bit_identical_to_scalar() {
    let isa = resolve(KernelIsa::Auto);
    forall("syrk isa ≡ scalar", 10, |rng| {
        // d straddles the 4-wide f64 lanes; n straddles the row tile.
        for d in [1usize, 3, 4, 5, 7, 8, 17] {
            for n in straddling_sizes(SYRK_ROW_TILE, rng) {
                let mut x = Matrix::zeros(n, d);
                for v in x.data.iter_mut() {
                    *v = if rng.next_bernoulli(0.1) {
                        0.0
                    } else {
                        rng.next_gaussian()
                    };
                }
                let w: Vec<f64> = (0..n).map(|_| rng.next_range_f64(-1.5, 1.5)).collect();
                let mut scalar = Matrix::zeros(d, d);
                let mut scratch = Vec::new();
                syrk_upper_blocked(&mut scalar, &x, &w, 0, n, &mut scratch);
                let mut dispatched = Matrix::zeros(d, d);
                let mut scratch2 = Vec::new();
                syrk_upper_blocked_isa(&mut dispatched, &x, &w, 0, n, &mut scratch2, isa);
                assert_eq!(dispatched.data, scalar.data, "n={n} d={d} isa={isa:?}");
            }
        }
    });
}

#[test]
fn prop_local_stats_isa_lane_straddling_dims_match_reference() {
    // Single-worker SIMD workspace ≡ the scalar ground truth, bitwise,
    // at dimensions that straddle the 4-wide lanes (dot, tile fill,
    // axpy and SYRK all see ragged tails here).
    let isa = resolve(KernelIsa::Auto);
    forall("local_stats isa ≡ reference", 8, |rng| {
        for d in [1usize, 3, 4, 5, 7, 8] {
            for n in [1usize, 5, SYRK_ROW_TILE - 1, SYRK_ROW_TILE + 1] {
                let (x, y, beta) = random_shard(n, d, rng);
                let reference = model::local_stats_reference(&x, &y, &beta);
                let mut ws = Workspace::with_isa(d, 1, isa);
                let mut got = LocalStats::zeros(d);
                model::local_stats_into(&mut ws, &x, &y, &beta, &mut got);
                assert_eq!(got.h.data, reference.h.data, "H: n={n} d={d} isa={isa:?}");
                assert_eq!(got.g, reference.g, "g: n={n} d={d}");
                assert_eq!(got.dev, reference.dev, "dev: n={n} d={d}");
            }
        }
    });
}

#[test]
fn prop_local_stats_isa_invariant_across_thread_counts() {
    // ISA composes with kernel_threads: at EVERY thread count the
    // SIMD workspace is bit-identical to the scalar workspace with
    // the same count (identical partition, per-range kernels
    // bit-identical, ordered merge).
    let isa = resolve(KernelIsa::Auto);
    forall("local_stats isa ≡ scalar × threads", 5, |rng| {
        let d = 2 + rng.next_below(8) as usize;
        let n = 8 * SYRK_ROW_TILE + 1 + rng.next_below(256) as usize;
        let (x, y, beta) = random_shard(n, d, rng);
        for threads in [1usize, 2, 4] {
            let mut ws_scalar = Workspace::with_isa(d, threads, Isa::Scalar);
            let mut ws_isa = Workspace::with_isa(d, threads, isa);
            let mut a = LocalStats::zeros(d);
            let mut b = LocalStats::zeros(d);
            model::local_stats_into(&mut ws_scalar, &x, &y, &beta, &mut a);
            model::local_stats_into(&mut ws_isa, &x, &y, &beta, &mut b);
            assert_eq!(a.h.data, b.h.data, "H: threads={threads} isa={isa:?}");
            assert_eq!(a.g, b.g, "g: threads={threads}");
            assert_eq!(a.dev, b.dev, "dev: threads={threads}");
        }
    });
}

#[test]
fn prop_share_batch_vandermonde_equals_horner() {
    forall("share_batch fast ≡ horner", 25, |rng| {
        let w = 1 + rng.next_below(7) as usize; // 1..=7 holders
        let t = 1 + rng.next_below(w as u64) as usize; // 1..=w
        let params = ShamirParams::new(t, w).unwrap();
        let table = VandermondeTable::new(params);
        for k in [0usize, 1, 2, 63, 64, 65] {
            let secrets: Vec<Fp> = (0..k).map(|_| Fp::random(rng)).collect();
            let seed = rng.next_u64();
            let mut r_fast = ChaCha20Rng::seed_from_u64(seed);
            let mut r_slow = ChaCha20Rng::seed_from_u64(seed);
            let fast = share_batch_with(&table, &secrets, &mut r_fast);
            let slow = share_batch_horner(params, &secrets, &mut r_slow);
            assert_eq!(fast.per_holder.len(), slow.per_holder.len());
            for j in 0..w {
                assert_eq!(
                    fast.per_holder[j], slow.per_holder[j],
                    "t={t} w={w} k={k} holder={j}"
                );
            }
            // identical RNG stream consumption
            assert_eq!(r_fast.next_u64(), r_slow.next_u64(), "stream diverged");
            // and the default entry point uses the fast path unchanged
            let mut r_pub = ChaCha20Rng::seed_from_u64(seed);
            let via_default = share_batch(params, &secrets, &mut r_pub);
            for j in 0..w {
                assert_eq!(via_default.per_holder[j], slow.per_holder[j]);
            }
        }
    });
}

#[test]
fn prop_fast_shares_still_reconstruct() {
    // End-to-end sanity on top of the equivalence: fast-path shares
    // reconstruct through any t-quorum.
    forall("fast shares reconstruct", 20, |rng| {
        let w = 2 + rng.next_below(5) as usize;
        let t = 1 + rng.next_below(w as u64) as usize;
        let params = ShamirParams::new(t, w).unwrap();
        let k = 1 + rng.next_below(40) as usize;
        let secrets: Vec<Fp> = (0..k).map(|_| Fp::random(rng)).collect();
        let mut crng = ChaCha20Rng::seed_from_u64(rng.next_u64());
        let batch = share_batch(params, &secrets, &mut crng);
        let mut holders: Vec<usize> = (0..w).collect();
        rng.shuffle(&mut holders);
        holders.truncate(t);
        let quorum: Vec<(usize, &[Fp])> = holders
            .iter()
            .map(|&j| (j, batch.per_holder[j].as_slice()))
            .collect();
        assert_eq!(reconstruct_batch(params, &quorum).unwrap(), secrets);
    });
}
