//! Property-based tests over the cryptographic and numeric substrates.
//!
//! `proptest` is not in the offline vendor set, so this file carries a
//! small in-crate property harness: each property runs against a few
//! hundred randomized cases from seeded generators, with the failing
//! seed printed on assertion failure for reproduction.

use privlr::field::{Fp, P};
use privlr::fixed::FixedCodec;
use privlr::linalg::{Cholesky, Matrix};
use privlr::model;
use privlr::protocol::{decode, encode, pack_upper, unpack_upper, HessianPayload, Message};
use privlr::shamir::{reconstruct_batch, share_batch, ShamirParams};
use privlr::util::rng::{ChaCha20Rng, Rng, SplitMix64};

/// Run `prop` for `cases` seeded iterations, reporting the seed on panic.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    for seed in 0..cases {
        let mut rng = SplitMix64::new(0xDEAD_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

#[test]
fn prop_field_ring_axioms() {
    forall("field ring axioms", 300, |rng| {
        let a = Fp::random(rng);
        let b = Fp::random(rng);
        let c = Fp::random(rng);
        // commutativity + associativity
        assert_eq!(a + b, b + a);
        assert_eq!((a + b) + c, a + (b + c));
        assert_eq!(a * b, b * a);
        assert_eq!((a * b) * c, a * (b * c));
        // distributivity
        assert_eq!(a * (b + c), a * b + a * c);
        // identities & inverses
        assert_eq!(a + Fp::ZERO, a);
        assert_eq!(a * Fp::ONE, a);
        assert_eq!(a - a, Fp::ZERO);
        if !a.is_zero() {
            assert_eq!(a * a.inv(), Fp::ONE);
        }
    });
}

#[test]
fn prop_shamir_roundtrip_any_quorum() {
    forall("shamir roundtrip", 120, |rng| {
        let w = 2 + (rng.next_below(6) as usize); // 2..=7 holders
        let t = 1 + (rng.next_below(w as u64) as usize); // 1..=w
        let params = ShamirParams::new(t, w).unwrap();
        let k = 1 + rng.next_below(20) as usize;
        let secrets: Vec<Fp> = (0..k).map(|_| Fp::random(rng)).collect();
        let mut crng = ChaCha20Rng::seed_from_u64(rng.next_u64());
        let batch = share_batch(params, &secrets, &mut crng);
        // random quorum of exactly t distinct holders
        let mut holders: Vec<usize> = (0..w).collect();
        rng.shuffle(&mut holders);
        holders.truncate(t);
        let quorum: Vec<(usize, &[Fp])> = holders
            .iter()
            .map(|&j| (j, batch.per_holder[j].as_slice()))
            .collect();
        assert_eq!(reconstruct_batch(params, &quorum).unwrap(), secrets);
    });
}

#[test]
fn prop_shamir_linearity() {
    // reconstruct(αA + B shares) == αA + B for random α, A, B.
    forall("shamir linearity", 100, |rng| {
        let params = ShamirParams::new(3, 5).unwrap();
        let a = Fp::random(rng);
        let b = Fp::random(rng);
        let alpha = Fp::random(rng);
        let mut crng = ChaCha20Rng::seed_from_u64(rng.next_u64());
        let ba = share_batch(params, &[a], &mut crng);
        let bb = share_batch(params, &[b], &mut crng);
        let combined: Vec<Vec<Fp>> = (0..5)
            .map(|j| vec![alpha * ba.per_holder[j][0] + bb.per_holder[j][0]])
            .collect();
        let quorum: Vec<(usize, &[Fp])> = [0usize, 3, 4]
            .iter()
            .map(|&j| (j, combined[j].as_slice()))
            .collect();
        assert_eq!(
            reconstruct_batch(params, &quorum).unwrap()[0],
            alpha * a + b
        );
    });
}

#[test]
fn prop_fixed_codec_roundtrip_and_additivity() {
    forall("fixed roundtrip", 200, |rng| {
        let codec = FixedCodec::default();
        let x = rng.next_range_f64(-1e6, 1e6);
        let y = rng.next_range_f64(-1e6, 1e6);
        let ex = codec.encode(x).unwrap();
        let ey = codec.encode(y).unwrap();
        assert!((codec.decode(ex) - x).abs() <= codec.epsilon());
        assert!((codec.decode(ex + ey) - (x + y)).abs() <= 2.0 * codec.epsilon());
        // negation symmetry
        let en = codec.encode(-x).unwrap();
        assert!((codec.decode(en) + x).abs() <= codec.epsilon());
    });
}

#[test]
fn prop_protocol_codec_roundtrip() {
    forall("protocol codec", 150, |rng| {
        let d = 1 + rng.next_below(12) as usize;
        let iter = rng.next_below(1000) as u32;
        let msg = match rng.next_below(4) {
            0 => Message::BetaBroadcast {
                iter,
                beta: (0..d).map(|_| rng.next_gaussian()).collect(),
            },
            1 => Message::ShareSubmission {
                iter,
                institution: rng.next_below(100) as u16,
                hessian: match rng.next_below(3) {
                    0 => HessianPayload::Plain(
                        (0..d * (d + 1) / 2).map(|_| rng.next_gaussian()).collect(),
                    ),
                    1 => HessianPayload::Shared(
                        (0..d * (d + 1) / 2).map(|_| Fp::random(rng)).collect(),
                    ),
                    _ => HessianPayload::Absent,
                },
                g_share: (0..d).map(|_| Fp::random(rng)).collect(),
                dev_share: Fp::random(rng),
            },
            2 => Message::AggregateRequest {
                iter,
                expected: rng.next_below(50) as u16,
            },
            _ => Message::SessionClose {
                iter,
                beta: (0..d).map(|_| rng.next_gaussian()).collect(),
            },
        };
        let bytes = encode(&msg);
        assert_eq!(decode(&bytes).unwrap(), msg);
        // prefix-truncation always fails cleanly, never panics
        if bytes.len() > 1 {
            let cut = 1 + rng.next_below((bytes.len() - 1) as u64) as usize;
            let _ = decode(&bytes[..cut]); // must not panic
        }
    });
}

#[test]
fn prop_pack_upper_roundtrip() {
    forall("pack_upper", 100, |rng| {
        let d = 1 + rng.next_below(16) as usize;
        let mut m = Matrix::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                m[(i, j)] = rng.next_gaussian();
            }
        }
        m.symmetrize();
        let back = unpack_upper(&pack_upper(&m), d);
        assert!(back.max_abs_diff(&m) == 0.0);
    });
}

#[test]
fn prop_cholesky_solves_random_spd() {
    forall("cholesky", 60, |rng| {
        let d = 1 + rng.next_below(12) as usize;
        let mut b = Matrix::zeros(d, d);
        for v in b.data.iter_mut() {
            *v = rng.next_gaussian();
        }
        let mut a = b.transpose().matmul(&b);
        a.add_diagonal(d as f64 + 1.0);
        let x_true: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let rhs = a.matvec(&x_true);
        let x = Cholesky::factor(&a).unwrap().solve(&rhs);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-7);
        }
    });
}

#[test]
fn prop_local_stats_shard_additivity() {
    // The decomposition property (Eqs. 4–6) on random shards/splits.
    forall("stats additivity", 40, |rng| {
        let d = 2 + rng.next_below(6) as usize;
        let n = 20 + rng.next_below(80) as usize;
        let mut x = Matrix::zeros(n, d);
        let mut y = vec![0.0; n];
        for i in 0..n {
            x[(i, 0)] = 1.0;
            for j in 1..d {
                x[(i, j)] = rng.next_gaussian();
            }
            y[i] = f64::from(rng.next_bernoulli(0.4));
        }
        let beta: Vec<f64> = (0..d).map(|_| rng.next_range_f64(-0.8, 0.8)).collect();
        let whole = model::local_stats(&x, &y, &beta);
        let cut = 1 + rng.next_below((n - 1) as u64) as usize;
        let take = |lo: usize, hi: usize| {
            let rows: Vec<Vec<f64>> = (lo..hi).map(|i| x.row(i).to_vec()).collect();
            model::local_stats(&Matrix::from_rows(rows), &y[lo..hi], &beta)
        };
        let mut merged = take(0, cut);
        merged.merge(&take(cut, n));
        assert!(whole.h.max_abs_diff(&merged.h) < 1e-9);
        assert!((whole.dev - merged.dev).abs() < 1e-9);
        for (a, b) in whole.g.iter().zip(&merged.g) {
            assert!((a - b).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_centered_lift_is_involutive() {
    forall("centered lift", 300, |rng| {
        // any value < p/2 in magnitude round-trips through the field
        let mag = (rng.next_u64() >> 4) as i128; // < 2^60 < p/2
        let v = if rng.next_bernoulli(0.5) { mag } else { -mag };
        assert_eq!(Fp::from_i128(v).to_i128_centered(), v);
    });
}

#[test]
fn prop_field_canonicality_preserved() {
    forall("canonical range", 200, |rng| {
        let a = Fp::random(rng);
        let b = Fp::random(rng);
        for v in [a + b, a - b, a * b, -a] {
            assert!(v.to_u64() < P);
        }
    });
}
