//! TCP-transport gates (`--features net`): the consortium over real
//! loopback sockets, one "process" per worker (its own `Network`,
//! `SessionRegistry`, and `TcpFabric` — nothing shared with the
//! coordinator but the wire).
//!
//! Three invariants:
//!
//! * **Bit-identity** — a loopback-TCP consortium fit reconstructs a β̂
//!   byte-identical to the in-memory transport, at 1 AND 2 driver
//!   shards. Specs never cross the wire: each worker derives its own
//!   from the shared config via `spec_for_consortium`, holding only its
//!   own shard's rows.
//! * **Crash-fault reuse** — killing an institution's sockets mid-fit
//!   flows through `WorkerDown` → `Suspended` → retry/backoff →
//!   `SessionReopen` replay exactly like a local worker crash, and a
//!   freshly attached replacement process finishes the fit with the
//!   same bytes. Zero session-state leaks on every survivor.
//! * **Hostile peers are inert** — raw sockets feeding garbage frame
//!   bodies and hostile length prefixes at a coordinator mid-fit are
//!   rejected (typed, counted, nothing allocated) without poisoning the
//!   live session or miscounting as worker loss.

#![cfg(feature = "net")]

use privlr::config::{ExperimentConfig, OnExhausted, SecurityMode};
use privlr::data::{synthetic, Dataset};
use privlr::engine::{EngineOptions, Lifecycle, RetryPolicy, StudyEngine, SubmitOptions};
use privlr::net::{NetOptions, TcpFabric, PREAMBLE};
use privlr::protocol::{Message, NodeId};
use privlr::session::{consortium_shards, spec_for_consortium, SessionRegistry, ShardData};
use privlr::transport::Network;
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// Link-frame kinds as documented in `net.rs`'s module doc — the raw
// hostile peers below speak the protocol off the documentation, as an
// attacker would.
const KIND_HELLO: u8 = 1;
const KIND_FRAME: u8 = 2;

fn cfg_3c() -> ExperimentConfig {
    ExperimentConfig {
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        ..ExperimentConfig::default()
    }
}

/// Full-security config: the shared-Hessian fit is heavy enough that
/// mid-fit interference (socket kills, hostile frames) reliably lands
/// while the session is still running.
fn heavy_cfg() -> ExperimentConfig {
    ExperimentConfig {
        mode: SecurityMode::Full,
        ..cfg_3c()
    }
}

fn await_lifecycle(engine: &StudyEngine, sid: u32, want: Lifecycle) {
    let t0 = Instant::now();
    while engine.lifecycle(sid) != Some(want) {
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "session {sid} never reached {want:?} (now {:?})",
            engine.lifecycle(sid)
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// One worker "process": its own network, registry, fabric, and worker
/// loop thread — reachable only through TCP.
struct RemoteWorker {
    node: NodeId,
    addr: SocketAddr,
    net: Arc<Network>,
    fabric: TcpFabric,
    gauge: Arc<AtomicUsize>,
    thread: std::thread::JoinHandle<anyhow::Result<()>>,
}

impl RemoteWorker {
    /// Derive specs for sessions `1..=sessions` locally (own shard
    /// only), listen, dial `dial`, and run the worker loop on a thread.
    fn spawn(
        node: NodeId,
        cfg: &ExperimentConfig,
        ds: &Dataset,
        sessions: u32,
        dial: &[SocketAddr],
    ) -> RemoteWorker {
        let institutions = ds.num_institutions();
        let d = ds.d();
        let own = match node {
            NodeId::Institution(j) => {
                Some((j as usize, ShardData::split(ds)[j as usize].clone()))
            }
            _ => None,
        };
        let registry = SessionRegistry::new();
        for s in 1..=sessions {
            registry.insert(
                spec_for_consortium(s, cfg, consortium_shards(institutions, d, own.clone()))
                    .unwrap(),
            );
        }
        let net = Network::new();
        let ep = net.register(node);
        let fabric = TcpFabric::new(&net, vec![node], NetOptions::default());
        let addr = fabric.listen("127.0.0.1:0").unwrap();
        for a in dial {
            fabric.connect(&a.to_string()).unwrap();
        }
        let gauge = Arc::new(AtomicUsize::new(0));
        let g = gauge.clone();
        let thread = std::thread::Builder::new()
            .name(format!("test-worker-{node}"))
            .spawn(move || match node {
                NodeId::Institution(j) => privlr::institution::run_institution_worker(
                    privlr::institution::InstitutionWorkerConfig {
                        institution_id: j,
                        registry,
                        engine: privlr::runtime::ComputeHandle::rust(),
                        live_sessions: g,
                    },
                    ep,
                ),
                NodeId::Center(c) => privlr::center::run_center_worker(
                    privlr::center::CenterWorkerConfig {
                        center_id: c,
                        registry,
                        live_sessions: g,
                    },
                    ep,
                ),
                other => panic!("not a worker role: {other}"),
            })
            .unwrap();
        RemoteWorker { node, addr, net, fabric, gauge, thread }
    }

    /// Stop the worker loop even when its TCP links are long gone: the
    /// engine's over-the-wire `Shutdown` is best-effort, so inject one
    /// locally too (harmless duplicate when the wire one landed).
    fn stop(self) -> anyhow::Result<()> {
        let _ = self
            .net
            .injector(NodeId::Coordinator)
            .send(self.node, &Message::Shutdown);
        let res = self.thread.join().expect("worker thread panicked");
        self.fabric.shutdown();
        res
    }
}

/// A coordinator-side consortium: remote-worker engine + fabric, with
/// every worker process spawned, dialed in, and awaited. Topology
/// mirrors `privlr serve`: centers and the coordinator listen,
/// institutions dial the coordinator and every center, centers dial the
/// coordinator.
struct Consortium {
    engine: StudyEngine,
    fabric: TcpFabric,
    coord_addr: SocketAddr,
    center_addrs: Vec<SocketAddr>,
    workers: Vec<RemoteWorker>,
}

impl Consortium {
    fn start(cfg: &ExperimentConfig, ds: &Dataset, driver_shards: usize, sessions: u32) -> Consortium {
        let institutions = ds.num_institutions();
        let centers = cfg.num_centers;
        let engine = StudyEngine::with_remote_workers(
            institutions,
            centers,
            EngineOptions {
                driver_shards,
                retry: RetryPolicy {
                    max_retries: 500,
                    backoff: Duration::from_millis(20),
                    on_exhausted: OnExhausted::Abort,
                },
                ..Default::default()
            },
        )
        .unwrap();
        let fabric = TcpFabric::new(&engine.network(), vec![NodeId::Coordinator], NetOptions::default());
        let coord_addr = fabric.listen("127.0.0.1:0").unwrap();
        fabric.supervise_for_engine(engine.driver_shards());

        let mut workers = Vec::new();
        let mut center_addrs = Vec::new();
        for c in 0..centers {
            let w = RemoteWorker::spawn(NodeId::Center(c as u16), cfg, ds, sessions, &[coord_addr]);
            center_addrs.push(w.addr);
            workers.push(w);
        }
        for j in 0..institutions {
            let mut dial = vec![coord_addr];
            dial.extend(center_addrs.iter().copied());
            workers.push(RemoteWorker::spawn(
                NodeId::Institution(j as u16),
                cfg,
                ds,
                sessions,
                &dial,
            ));
        }
        let expected: Vec<NodeId> = workers.iter().map(|w| w.node).collect();
        fabric
            .await_peers(&expected, Duration::from_secs(60))
            .expect("consortium never fully connected");
        Consortium { engine, fabric, coord_addr, center_addrs, workers }
    }

    /// Leak gates + orderly teardown. `skip_gauge` names workers whose
    /// gauge must NOT be asserted (a killed process legitimately holds
    /// the state its replacement replayed past).
    fn finish(self, skip_gauge: &[NodeId]) {
        assert_eq!(self.engine.live_specs(), 0, "coordinator leaked session specs");
        // Ships `Shutdown` to every remote worker over the live links.
        self.engine.shutdown().unwrap();
        for w in self.workers {
            if !skip_gauge.contains(&w.node) {
                assert_eq!(
                    w.gauge.load(Ordering::Relaxed),
                    0,
                    "worker {} leaked session state",
                    w.node
                );
            }
            w.stop().unwrap();
        }
        self.fabric.shutdown();
    }
}

/// In-memory reference: K sequential submissions on a fresh engine get
/// session ids 1..=K — the same ids the consortium workers pre-register
/// — so every share stream derives from identical `(seed, session,
/// institution)` triples.
fn baseline_betas(cfg: &ExperimentConfig, ds: &Dataset, sessions: u32) -> Vec<Vec<f64>> {
    let engine = StudyEngine::new(ds.num_institutions(), cfg.num_centers).unwrap();
    let handles: Vec<_> = (0..sessions)
        .map(|_| engine.submit(cfg, ds, SubmitOptions::batch()).unwrap())
        .collect();
    let betas = handles.into_iter().map(|h| h.join().unwrap().beta).collect();
    engine.shutdown().unwrap();
    betas
}

/// Loopback-TCP ≡ in-memory, bitwise, at 1 and 2 driver shards.
#[test]
fn loopback_tcp_fit_is_bit_identical_to_in_memory() {
    let ds = synthetic("net-bitid", 600, 4, 2, 0.0, 1.0, 901);
    let cfg = cfg_3c();
    let base = baseline_betas(&cfg, &ds, 2);
    for driver_shards in [1usize, 2] {
        let consortium = Consortium::start(&cfg, &ds, driver_shards, 2);
        let shards = consortium_shards(ds.num_institutions(), ds.d(), None);
        let handles: Vec<_> = (0..2)
            .map(|_| {
                consortium
                    .engine
                    .submit_shared(&cfg, shards.clone(), SubmitOptions::batch())
                    .unwrap()
            })
            .collect();
        let betas: Vec<Vec<f64>> = handles
            .into_iter()
            .map(|h| h.join().unwrap().beta)
            .collect();
        assert_eq!(
            betas, base,
            "TCP transport moved the numerics at {driver_shards} driver shard(s)"
        );
        let stats = consortium.fabric.stats();
        assert!(stats.frames_out > 0 && stats.frames_in > 0, "fit never used the wire");
        assert_eq!(stats.rejected_frames, 0);
        assert_eq!(stats.disconnects, 0);
        consortium.finish(&[]);
    }
}

/// Kill every socket of one institution process mid-fit, attach a
/// fresh replacement process, and require the recovered β̂ to be
/// byte-identical to an uninterrupted in-memory fit — the remote link
/// loss must ride the exact `Suspended` → `SessionReopen` replay path
/// a local worker crash does.
#[test]
fn mid_fit_socket_kill_recovers_bit_identically_via_replay() {
    let ds = synthetic("net-kill", 4000, 5, 2, 0.0, 1.0, 902);
    let cfg = heavy_cfg();
    let base = baseline_betas(&cfg, &ds, 1);

    let mut consortium = Consortium::start(&cfg, &ds, 1, 1);
    let shards = consortium_shards(ds.num_institutions(), ds.d(), None);
    let h = consortium
        .engine
        .submit_shared(&cfg, shards, SubmitOptions::batch())
        .unwrap();
    let sid = h.session_id();
    await_lifecycle(&consortium.engine, sid, Lifecycle::Running);

    // Yank institution 1's sockets out from under the live fit.
    let pos = consortium
        .workers
        .iter()
        .position(|w| w.node == NodeId::Institution(1))
        .unwrap();
    let victim = consortium.workers.remove(pos);
    victim.fabric.shutdown();

    // A replacement process dials in; the driver's retry loop keeps
    // re-sending `SessionReopen` (typed `PeerUnknown` failures in
    // between) until the new HELLO restores the route, then replays.
    let mut dial = vec![consortium.coord_addr];
    dial.extend(consortium.center_addrs.iter().copied());
    let replacement = RemoteWorker::spawn(NodeId::Institution(1), &cfg, &ds, 1, &dial);
    consortium.workers.push(replacement);

    let fit = h.join().expect("fit must survive the socket kill");
    assert_eq!(fit.beta, base[0], "replay over TCP moved the numerics");
    assert_eq!(consortium.engine.lifecycle(sid), Some(Lifecycle::Closed));
    assert!(
        consortium.fabric.stats().disconnects >= 1,
        "the supervisor never classified the socket kill as a worker loss"
    );
    consortium.finish(&[]);
    // The dead process still holds whatever state the cut stranded;
    // stop its blocked loop via the local injector.
    victim.stop().unwrap();
}

/// Hostile raw peers mid-fit: garbage frame bodies are dropped (typed,
/// counted, link kept), a hostile length prefix kills only its own
/// link before any allocation, and the live session's β̂ comes out
/// byte-identical — no poisoning, and none of it counts as worker loss.
#[test]
fn hostile_raw_frames_do_not_poison_live_sessions() {
    let ds = synthetic("net-hostile", 2000, 4, 2, 0.0, 1.0, 903);
    let cfg = heavy_cfg();
    let base = baseline_betas(&cfg, &ds, 1);

    let consortium = Consortium::start(&cfg, &ds, 1, 1);
    let shards = consortium_shards(ds.num_institutions(), ds.d(), None);
    let h = consortium
        .engine
        .submit_shared(&cfg, shards, SubmitOptions::batch())
        .unwrap();
    await_lifecycle(&consortium.engine, h.session_id(), Lifecycle::Running);

    // Attacker 1 completes the handshake (empty HELLO — claims no
    // nodes) and ships FRAMEs whose wire bodies are garbage.
    let mut attacker = TcpStream::connect(consortium.coord_addr).unwrap();
    attacker.write_all(&PREAMBLE).unwrap();
    let mut hello = Vec::new();
    hello.extend_from_slice(&3u32.to_le_bytes());
    hello.push(KIND_HELLO);
    hello.extend_from_slice(&0u16.to_le_bytes());
    attacker.write_all(&hello).unwrap();
    for _ in 0..3 {
        let mut payload = Vec::new();
        payload.extend_from_slice(&[1, 0, 0]); // from: Institution(0)
        payload.extend_from_slice(&[0, 0, 0]); // to: Coordinator
        payload.extend_from_slice(&[0xAB; 32]); // body: not a wire frame
        let mut frame = Vec::new();
        frame.extend_from_slice(&((1 + payload.len()) as u32).to_le_bytes());
        frame.push(KIND_FRAME);
        frame.extend_from_slice(&payload);
        attacker.write_all(&frame).unwrap();
    }

    // Attacker 2 sends a hostile length prefix straight after the
    // preamble — must die before any allocation happens.
    let mut attacker2 = TcpStream::connect(consortium.coord_addr).unwrap();
    attacker2.write_all(&PREAMBLE).unwrap();
    attacker2.write_all(&u32::MAX.to_le_bytes()).unwrap();

    let deadline = Instant::now() + Duration::from_secs(10);
    while (consortium.fabric.stats().rejected_frames < 3
        || consortium.fabric.stats().oversized_frames < 1)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = consortium.fabric.stats();
    assert_eq!(stats.rejected_frames, 3, "garbage bodies must be dropped and counted");
    assert_eq!(stats.oversized_frames, 1, "hostile prefix must be rejected pre-allocation");

    let fit = h.join().expect("hostile peers must not break the fit");
    assert_eq!(fit.beta, base[0], "hostile frames poisoned a live session");
    assert_eq!(
        consortium.fabric.stats().disconnects,
        0,
        "hostile peers must not be classified as worker loss"
    );
    drop(attacker);
    drop(attacker2);
    consortium.finish(&[]);
}
