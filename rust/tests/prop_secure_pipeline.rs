//! Acceptance gates for the zero-allocation threaded secure-sharing
//! pipeline (encode → share → fold → reconstruct):
//!
//! * the fused threaded `secure::encode_share_into` sweep is **bitwise
//!   identical across `kernel_threads ∈ {1, 2, 4}`** — including batch
//!   lengths that straddle `shamir::SHARE_CHUNK` boundaries — because
//!   every chunk draws its coefficients from an independent stream
//!   keyed by the chunk index, never by the thread layout;
//! * any t-quorum of the fused sweep's shares reconstructs to exactly
//!   the same field values as the retained `share_batch_with`
//!   reference path over `FixedCodec::encode_slice`;
//! * the lazy-reduction kernels agree with the eager formulas at the
//!   field boundary (values near P) and at max-headroom encodings;
//! * after warm-up, one full single-threaded pipeline iteration
//!   (encode+share, per-center fold, cached-λ reconstruction, decode)
//!   performs **zero heap allocations** — verified with a counting
//!   global allocator, not by inspection;
//! * ISA invariance: the `simd::resolve(Auto)`-dispatched share
//!   evaluation and reconstruction are **bit-identical** to the scalar
//!   reference at lane- and chunk-straddling lengths, near-P and
//!   max-headroom values, across `kernel_threads ∈ {1, 2, 4}`.

use privlr::config::KernelIsa;
use privlr::field::{add_assign_slice, Fp, P};
use privlr::fixed::FixedCodec;
use privlr::secure::{encode_share_into, encode_share_into_isa, ShareContext, SharePool};
use privlr::shamir::{
    lagrange_at_zero, reconstruct_batch, reconstruct_batch_with, reconstruct_batch_with_isa,
    reconstruct_scalar_with, LagrangeCache, ShamirParams, SHARE_CHUNK,
};
use privlr::simd::resolve;
use privlr::util::rng::{ChaCha20Rng, Rng, SplitMix64};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- thread-local allocation counter ------------------------------------
//
// Counts allocations made by THIS thread only, so the gate is immune to
// the test harness's other worker threads. `Cell<u64>` has no
// destructor, so the TLS access can never recurse into the allocator.

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers ------------------------------------------------------------

fn scheme(t: usize, w: usize) -> ShamirParams {
    ShamirParams::new(t, w).unwrap()
}

/// Gate 1: thread-count invariance of the fused sweep, across lengths
/// that straddle the chunk boundary and schemes including t=1 and t=w.
#[test]
fn fused_sweep_bit_identical_across_thread_counts() {
    for (t, w) in [(1usize, 3usize), (2, 3), (3, 5), (5, 5)] {
        let params = scheme(t, w);
        let ctx = ShareContext::new(params);
        let codec = FixedCodec::default();
        for k in [
            0usize,
            1,
            SHARE_CHUNK - 1,
            SHARE_CHUNK,
            SHARE_CHUNK + 1,
            3 * SHARE_CHUNK + 7,
        ] {
            let mut rng = SplitMix64::new((t * 100 + w * 10) as u64 + k as u64);
            let values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-1e5, 1e5)).collect();
            let mut reference_pool = SharePool::new();
            encode_share_into(&ctx, &codec, &values, 0xABCD, 1, &mut reference_pool).unwrap();
            for threads in [2usize, 4] {
                let mut pool = SharePool::new();
                encode_share_into(&ctx, &codec, &values, 0xABCD, threads, &mut pool).unwrap();
                for j in 0..w {
                    assert_eq!(
                        reference_pool.holder(j),
                        pool.holder(j),
                        "t={t} w={w} k={k} threads={threads} holder={j}"
                    );
                }
            }
        }
    }
}

/// Gate 2: the fused pipeline reconstructs to EXACTLY the field values
/// the retained `share_batch_with` reference path reconstructs to —
/// for every t-quorum, with chunk-straddling batch lengths.
#[test]
fn fused_sweep_reconstruction_equals_reference_path() {
    let params = scheme(3, 5);
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    for k in [1usize, SHARE_CHUNK, SHARE_CHUNK + 1, 2 * SHARE_CHUNK + 13] {
        let mut rng = SplitMix64::new(k as u64);
        let values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-1e4, 1e4)).collect();
        let enc = codec.encode_slice(&values).unwrap();
        // reference: eager Vandermonde over a session ChaCha stream
        let mut ref_rng = ChaCha20Rng::seed_from_u64(500 + k as u64);
        let reference = ctx.share(&enc, &mut ref_rng);
        // fused threaded sweep
        let mut pool = SharePool::new();
        encode_share_into(&ctx, &codec, &values, 900 + k as u64, 4, &mut pool).unwrap();
        for quorum_idx in [[0usize, 1, 2], [2, 3, 4], [0, 2, 4], [4, 1, 0]] {
            let fused_q: Vec<(usize, &[Fp])> =
                quorum_idx.iter().map(|&j| (j, pool.holder(j))).collect();
            let ref_q: Vec<(usize, &[Fp])> = quorum_idx
                .iter()
                .map(|&j| (j, reference.per_holder[j].as_slice()))
                .collect();
            let from_fused = reconstruct_batch(params, &fused_q).unwrap();
            let from_ref = reconstruct_batch(params, &ref_q).unwrap();
            assert_eq!(from_fused, enc, "k={k} quorum {quorum_idx:?}");
            assert_eq!(from_fused, from_ref, "k={k} quorum {quorum_idx:?}");
        }
    }
}

/// Gate 3a: lazy-reduction reconstruction at the field boundary. Share
/// vectors stuffed with values near P must reconstruct identically to
/// the eager per-term formula.
#[test]
fn lazy_reconstruction_boundary_values_near_p() {
    let params = scheme(4, 9);
    let idx = [0usize, 3, 5, 8];
    let lambdas = lagrange_at_zero(params, &idx).unwrap();
    let boundary = [P - 1, P - 2, 1, 0, P / 2, P / 2 + 1];
    let shares: Vec<Vec<Fp>> = (0..4u64)
        .map(|j| boundary.iter().map(|&v| Fp::new(v.wrapping_add(j))).collect())
        .collect();
    let quorum: Vec<(usize, &[Fp])> = idx
        .iter()
        .zip(&shares)
        .map(|(&j, s)| (j, s.as_slice()))
        .collect();
    let mut lazy = vec![Fp::ZERO; boundary.len()];
    reconstruct_batch_with(&lambdas, &quorum, &mut lazy).unwrap();
    for k in 0..boundary.len() {
        let eager = quorum
            .iter()
            .zip(&lambdas)
            .fold(Fp::ZERO, |acc, ((_, s), &l)| acc + l * s[k]);
        assert_eq!(lazy[k], eager, "element {k}");
    }
    let scalars: Vec<Fp> = shares.iter().map(|s| s[0]).collect();
    assert_eq!(reconstruct_scalar_with(&lambdas, &scalars), lazy[0]);
}

/// Gate 3b: max-headroom encodings survive the whole pipeline. Every
/// value at ±`FixedCodec::max_abs` — the largest magnitude the codec
/// admits — must share, fold across a full 256-way aggregation budget
/// worth of institutions, and decode back exactly.
#[test]
fn max_headroom_encodings_roundtrip_through_pipeline() {
    let params = scheme(3, 5);
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let k = SHARE_CHUNK + 3;
    let values: Vec<f64> = (0..k)
        .map(|i| if i % 2 == 0 { codec.max_abs() } else { -codec.max_abs() })
        .collect();
    // Two institutions' worth of shares folded per center (secure add).
    let mut pool_a = SharePool::new();
    let mut pool_b = SharePool::new();
    encode_share_into(&ctx, &codec, &values, 1, 2, &mut pool_a).unwrap();
    encode_share_into(&ctx, &codec, &values, 2, 2, &mut pool_b).unwrap();
    let folded: Vec<Vec<Fp>> = (0..5)
        .map(|c| {
            let mut acc = pool_a.holder(c).to_vec();
            add_assign_slice(&mut acc, pool_b.holder(c));
            acc
        })
        .collect();
    let quorum: Vec<(usize, &[Fp])> = [1usize, 2, 4]
        .iter()
        .map(|&c| (c, folded[c].as_slice()))
        .collect();
    let rec = reconstruct_batch(params, &quorum).unwrap();
    let decoded = FixedCodec::default().decode_slice(&rec);
    for (i, v) in decoded.iter().enumerate() {
        let expect = 2.0 * values[i];
        assert!(
            (v - expect).abs() <= 2.0 * codec.epsilon(),
            "element {i}: {v} vs {expect}"
        );
    }
}

/// Gate 4: after warm-up, one single-threaded pipeline iteration —
/// fused encode+share of a d=85 full-mode summary, per-center folds,
/// cached-λ reconstruction of g/dev/H, decode — allocates NOTHING.
/// Measured with the counting allocator, on this thread.
#[test]
fn warm_pipeline_iteration_is_allocation_free() {
    let d = 85usize;
    let packed = d * (d + 1) / 2;
    let k = d + 1 + packed; // [g | dev | H] summary layout
    let params = scheme(3, 5);
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let mut rng = SplitMix64::new(7);
    let values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-100.0, 100.0)).collect();
    let mut pool = SharePool::new();
    let mut accs: Vec<Vec<Fp>> = (0..5).map(|_| vec![Fp::ZERO; k]).collect();
    let mut lagrange = LagrangeCache::new();
    let mut fp_out = vec![Fp::ZERO; k];
    let mut f64_out = vec![0.0; k];

    let mut iteration = |seed: u64,
                         pool: &mut SharePool,
                         accs: &mut Vec<Vec<Fp>>,
                         lagrange: &mut LagrangeCache,
                         fp_out: &mut [Fp],
                         f64_out: &mut [f64]| {
        // encode + share (threads=1: the strictly allocation-free path)
        encode_share_into(&ctx, &codec, &values, seed, 1, pool).unwrap();
        // center-side fold: two "institutions" (the same sweep twice)
        for (c, acc) in accs.iter_mut().enumerate() {
            acc.fill(Fp::ZERO);
            add_assign_slice(acc, pool.holder(c));
            add_assign_slice(acc, pool.holder(c));
        }
        // coordinator-side cached-λ reconstruction + decode
        let lambdas = lagrange.zero_weights(params, &[0, 2, 4]).unwrap();
        let quorum: [(usize, &[Fp]); 3] = [
            (0, accs[0].as_slice()),
            (2, accs[2].as_slice()),
            (4, accs[4].as_slice()),
        ];
        reconstruct_batch_with(lambdas, &quorum, fp_out).unwrap();
        codec.decode_slice_into(fp_out, f64_out);
        f64_out[0]
    };

    // Warm-up: grows every pooled buffer and fills the λ cache.
    for warm in 0..3u64 {
        iteration(warm, &mut pool, &mut accs, &mut lagrange, &mut fp_out, &mut f64_out);
    }
    // Measured iterations: zero allocations on this thread.
    let before = allocs_here();
    for seed in 100..104u64 {
        iteration(seed, &mut pool, &mut accs, &mut lagrange, &mut fp_out, &mut f64_out);
    }
    let allocated = allocs_here() - before;
    assert_eq!(
        allocated, 0,
        "warm single-threaded pipeline iterations must not allocate"
    );

    // Sanity: the measured iterations actually computed the aggregate.
    for (i, v) in f64_out.iter().enumerate() {
        let expect = 2.0 * values[i];
        assert!((v - expect).abs() <= 2.0 * codec.epsilon(), "element {i}");
    }
}

// ---- Gate 5: ISA invariance (scalar ≡ simd, bitwise) --------------------
//
// `simd::resolve(Auto)` yields Simd exactly when this host can run the
// AVX2 kernels; where it yields Scalar these gates compare the
// reference against itself and pass trivially. On AVX2 hardware the
// same gates are the vector-vs-scalar bit-identity proof for the
// 4-lane Mersenne share arithmetic, with no cfg-juggling here.

/// Gate 5a: the ISA-dispatched fused share sweep produces exactly the
/// scalar reference's holder buffers — at lane-straddling lengths
/// (1..=33) and chunk-straddling lengths (`SHARE_CHUNK`±1), with
/// max-headroom encodings mixed in so lane residues sit near P, across
/// `kernel_threads ∈ {1, 2, 4}`.
#[test]
fn isa_share_evaluation_bit_identical_to_scalar() {
    let isa = resolve(KernelIsa::Auto);
    let params = scheme(3, 5);
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    for k in [
        1usize,
        3,
        4,
        5,
        7,
        8,
        31,
        32,
        33,
        SHARE_CHUNK - 1,
        SHARE_CHUNK,
        SHARE_CHUNK + 1,
    ] {
        let mut rng = SplitMix64::new(0x15A_0000 + k as u64);
        let mut values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-1e5, 1e5)).collect();
        values[0] = codec.max_abs();
        if k > 1 {
            values[k - 1] = -codec.max_abs();
        }
        let mut scalar_pool = SharePool::new();
        encode_share_into(&ctx, &codec, &values, 0x5EED, 1, &mut scalar_pool).unwrap();
        for threads in [1usize, 2, 4] {
            let mut pool = SharePool::new();
            encode_share_into_isa(&ctx, &codec, &values, 0x5EED, threads, isa, &mut pool)
                .unwrap();
            for j in 0..5 {
                assert_eq!(
                    scalar_pool.holder(j),
                    pool.holder(j),
                    "k={k} threads={threads} holder={j} isa={isa:?}"
                );
            }
        }
    }
}

/// Gate 5b: the ISA-dispatched batch reconstruction is bit-identical
/// to the scalar lazy-fold reference at the same lengths, with the
/// leading elements pinned to the field-boundary values near P (the
/// SIMD accumulator's worst case for deferred folds).
#[test]
fn isa_reconstruction_bit_identical_to_scalar() {
    let isa = resolve(KernelIsa::Auto);
    let params = scheme(4, 9);
    let idx = [0usize, 3, 5, 8];
    let lambdas = lagrange_at_zero(params, &idx).unwrap();
    let boundary = [P - 1, P - 2, 1, 0, P / 2, P / 2 + 1];
    for k in [1usize, 3, 4, 5, 7, 8, 31, 32, 33, SHARE_CHUNK + 1] {
        let mut rng = SplitMix64::new(0x15A_1000 + k as u64);
        let shares: Vec<Vec<Fp>> = (0..4u64)
            .map(|j| {
                (0..k)
                    .map(|i| {
                        if i < boundary.len() {
                            Fp::new(boundary[i].wrapping_add(j))
                        } else {
                            Fp::new(rng.next_below(P))
                        }
                    })
                    .collect()
            })
            .collect();
        let quorum: Vec<(usize, &[Fp])> = idx
            .iter()
            .zip(&shares)
            .map(|(&j, s)| (j, s.as_slice()))
            .collect();
        let mut scalar_out = vec![Fp::ZERO; k];
        reconstruct_batch_with(&lambdas, &quorum, &mut scalar_out).unwrap();
        let mut isa_out = vec![Fp::ZERO; k];
        reconstruct_batch_with_isa(&lambdas, &quorum, &mut isa_out, isa).unwrap();
        assert_eq!(scalar_out, isa_out, "k={k} isa={isa:?}");
    }
}

/// End-to-end property: the fused pipeline's decoded aggregates equal
/// the plaintext sums for a multi-institution fold, independently of
/// the thread count used by each institution.
#[test]
fn pipeline_aggregate_equals_plaintext_sums() {
    let params = scheme(2, 4);
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let k = 2 * SHARE_CHUNK + 31;
    let mut rng = SplitMix64::new(17);
    let per_inst: Vec<Vec<f64>> = (0..3)
        .map(|_| (0..k).map(|_| rng.next_range_f64(-50.0, 50.0)).collect())
        .collect();
    for threads in [1usize, 2, 4] {
        let mut accs: Vec<Vec<Fp>> = (0..4).map(|_| vec![Fp::ZERO; k]).collect();
        let mut pool = SharePool::new();
        for (j, vals) in per_inst.iter().enumerate() {
            encode_share_into(&ctx, &codec, vals, j as u64, threads, &mut pool).unwrap();
            for (c, acc) in accs.iter_mut().enumerate() {
                add_assign_slice(acc, pool.holder(c));
            }
        }
        let quorum: Vec<(usize, &[Fp])> =
            [0usize, 3].iter().map(|&c| (c, accs[c].as_slice())).collect();
        let rec = reconstruct_batch(params, &quorum).unwrap();
        let decoded = codec.decode_slice(&rec);
        for i in 0..k {
            let expect: f64 = per_inst.iter().map(|v| v[i]).sum();
            assert!(
                (decoded[i] - expect).abs() <= 3.0 * codec.epsilon(),
                "threads={threads} element {i}: {} vs {expect}",
                decoded[i]
            );
        }
    }
}
