//! Property tests for the session-tagged frame codec: every `Message`
//! variant round-trips under every session id class (0, small, large,
//! u32::MAX), frame sizes are exactly header + body, and malformed
//! frames are rejected.

use privlr::field::Fp;
use privlr::protocol::{
    decode, decode_frame, encode, encode_frame, encode_share_submission, HessianPayload,
    HessianRef, Message, SessionId, CONTROL_SESSION, SESSION_HEADER_LEN,
};
use privlr::util::rng::{Rng, SplitMix64};

/// One representative of every `Message` variant, parameterized by an
/// RNG so repeated calls exercise different payload shapes/sizes.
fn all_variants(rng: &mut SplitMix64) -> Vec<Message> {
    let d = 1 + (rng.next_u64() % 12) as usize;
    let fps = |rng: &mut SplitMix64, n: usize| -> Vec<Fp> {
        (0..n).map(|_| Fp::new(rng.next_u64())).collect()
    };
    let f64s = |rng: &mut SplitMix64, n: usize| -> Vec<f64> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    };
    vec![
        Message::BetaBroadcast {
            iter: rng.next_u64() as u32,
            beta: f64s(rng, d),
        },
        Message::ShareSubmission {
            iter: 1,
            institution: rng.next_u64() as u16,
            hessian: HessianPayload::Plain(f64s(rng, d * (d + 1) / 2)),
            g_share: fps(rng, d),
            dev_share: Fp::new(rng.next_u64()),
        },
        Message::ShareSubmission {
            iter: 2,
            institution: 0,
            hessian: HessianPayload::Shared(fps(rng, d * (d + 1) / 2)),
            g_share: fps(rng, d),
            dev_share: Fp::ZERO,
        },
        Message::ShareSubmission {
            iter: 3,
            institution: 5,
            hessian: HessianPayload::Absent,
            g_share: fps(rng, d),
            dev_share: Fp::new(7),
        },
        Message::AggregateRequest {
            iter: rng.next_u64() as u32,
            expected: rng.next_u64() as u16,
        },
        Message::AggregateResponse {
            iter: 4,
            center: rng.next_u64() as u16,
            hessian: HessianPayload::Plain(f64s(rng, d)),
            g_share: fps(rng, d),
            dev_share: Fp::new(99),
        },
        Message::SessionClose {
            iter: 6,
            beta: f64s(rng, d),
        },
        Message::SessionClose {
            iter: 7,
            beta: vec![],
        },
        Message::CloseAck {
            node: rng.next_u64() as u16,
            is_center: rng.next_bernoulli(0.5),
        },
        Message::Abort {
            reason: format!("abort-{}", rng.next_u64()),
        },
        Message::Abort {
            reason: String::new(),
        },
        Message::NodeError {
            node: rng.next_u64() as u16,
            is_center: rng.next_bernoulli(0.5),
            error: format!("err-{}", rng.next_u64()),
        },
        Message::StudySubmitted,
        Message::AdmissionWake,
        Message::Shutdown,
    ]
}

const SESSIONS: [SessionId; 6] = [CONTROL_SESSION, 1, 2, 4096, u32::MAX - 1, u32::MAX];

#[test]
fn every_variant_roundtrips_under_every_session_id() {
    let mut rng = SplitMix64::new(2024);
    for round in 0..8 {
        for msg in all_variants(&mut rng) {
            for session in SESSIONS {
                let frame = encode_frame(session, &msg);
                let (s, back) = decode_frame(&frame).unwrap();
                assert_eq!(s, session, "round {round}");
                assert_eq!(back, msg, "round {round} session {session}");
            }
        }
    }
}

#[test]
fn frame_is_exactly_header_plus_body() {
    let mut rng = SplitMix64::new(7);
    for msg in all_variants(&mut rng) {
        let body = encode(&msg);
        for session in SESSIONS {
            let frame = encode_frame(session, &msg);
            assert_eq!(frame.len(), SESSION_HEADER_LEN + body.len());
            assert_eq!(&frame[..SESSION_HEADER_LEN], session.to_le_bytes());
            assert_eq!(&frame[SESSION_HEADER_LEN..], &body[..]);
            // the body alone still decodes with the plain codec
            assert_eq!(decode(&frame[SESSION_HEADER_LEN..]).unwrap(), msg);
        }
    }
}

#[test]
fn truncated_frames_are_rejected_at_every_length() {
    let mut rng = SplitMix64::new(99);
    for msg in all_variants(&mut rng) {
        let frame = encode_frame(3, &msg);
        for cut in 0..frame.len() {
            assert!(
                decode_frame(&frame[..cut]).is_err(),
                "cut at {cut}/{} must fail for {}",
                frame.len(),
                msg.kind()
            );
        }
        // ... and the full frame still decodes.
        assert!(decode_frame(&frame).is_ok());
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    let mut rng = SplitMix64::new(5);
    for msg in all_variants(&mut rng) {
        let mut frame = encode_frame(1, &msg);
        frame.push(0);
        assert!(decode_frame(&frame).is_err(), "{}", msg.kind());
    }
}

/// The zero-copy submission encoder must be byte-identical to the
/// Message-based codec for every payload shape and session id class —
/// this equality is what lets the institution hot path skip the owned
/// `Vec` copies without any risk to decoding or traffic accounting.
#[test]
fn zero_copy_submission_encoder_matches_message_codec() {
    let mut rng = SplitMix64::new(4242);
    for _ in 0..16 {
        let d = 1 + (rng.next_u64() % 16) as usize;
        let packed = d * (d + 1) / 2;
        let g: Vec<Fp> = (0..d).map(|_| Fp::new(rng.next_u64())).collect();
        let dev = Fp::new(rng.next_u64());
        let iter = rng.next_u64() as u32;
        let institution = rng.next_u64() as u16;
        let h_plain: Vec<f64> = (0..packed).map(|_| rng.next_gaussian()).collect();
        let h_shared: Vec<Fp> = (0..packed).map(|_| Fp::new(rng.next_u64())).collect();
        for session in SESSIONS {
            let cases: Vec<(HessianRef, HessianPayload)> = vec![
                (
                    HessianRef::Plain(&h_plain),
                    HessianPayload::Plain(h_plain.clone()),
                ),
                (
                    HessianRef::Shared(&h_shared),
                    HessianPayload::Shared(h_shared.clone()),
                ),
                (HessianRef::Absent, HessianPayload::Absent),
            ];
            for (href, hpay) in cases {
                let fast = encode_share_submission(session, iter, institution, href, &g, dev);
                let slow = encode_frame(
                    session,
                    &Message::ShareSubmission {
                        iter,
                        institution,
                        hessian: hpay,
                        g_share: g.clone(),
                        dev_share: dev,
                    },
                );
                assert_eq!(fast, slow, "session {session} d={d}");
            }
        }
    }
}

#[test]
fn out_of_range_field_elements_are_rejected_in_frames() {
    let msg = Message::ShareSubmission {
        iter: 0,
        institution: 0,
        hessian: HessianPayload::Absent,
        g_share: vec![Fp::new(5)],
        dev_share: Fp::new(6),
    };
    let mut frame = encode_frame(2, &msg);
    let n = frame.len();
    // dev_share is the trailing 8 bytes; overwrite with u64::MAX (≥ P).
    frame[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(decode_frame(&frame).is_err());
}
