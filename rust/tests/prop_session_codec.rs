//! Property tests for the session-tagged frame codec: every `Message`
//! variant round-trips under every session id class (0, small, large,
//! u32::MAX), frame sizes are exactly header + body, and malformed
//! frames are rejected.

use privlr::field::Fp;
use privlr::protocol::{
    decode, decode_frame, encode, encode_frame, encode_share_submission, HessianPayload,
    HessianRef, Message, SessionId, CONTROL_SESSION, SESSION_HEADER_LEN,
};
use privlr::util::rng::{Rng, SplitMix64};

/// One representative of every `Message` variant, parameterized by an
/// RNG so repeated calls exercise different payload shapes/sizes.
fn all_variants(rng: &mut SplitMix64) -> Vec<Message> {
    let d = 1 + (rng.next_u64() % 12) as usize;
    let fps = |rng: &mut SplitMix64, n: usize| -> Vec<Fp> {
        (0..n).map(|_| Fp::new(rng.next_u64())).collect()
    };
    let f64s = |rng: &mut SplitMix64, n: usize| -> Vec<f64> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    };
    vec![
        Message::BetaBroadcast {
            iter: rng.next_u64() as u32,
            beta: f64s(rng, d),
        },
        Message::ShareSubmission {
            iter: 1,
            institution: rng.next_u64() as u16,
            hessian: HessianPayload::Plain(f64s(rng, d * (d + 1) / 2)),
            g_share: fps(rng, d),
            dev_share: Fp::new(rng.next_u64()),
        },
        Message::ShareSubmission {
            iter: 2,
            institution: 0,
            hessian: HessianPayload::Shared(fps(rng, d * (d + 1) / 2)),
            g_share: fps(rng, d),
            dev_share: Fp::ZERO,
        },
        Message::ShareSubmission {
            iter: 3,
            institution: 5,
            hessian: HessianPayload::Absent,
            g_share: fps(rng, d),
            dev_share: Fp::new(7),
        },
        Message::AggregateRequest {
            iter: rng.next_u64() as u32,
            expected: rng.next_u64() as u16,
        },
        Message::AggregateResponse {
            iter: 4,
            center: rng.next_u64() as u16,
            hessian: HessianPayload::Plain(f64s(rng, d)),
            g_share: fps(rng, d),
            dev_share: Fp::new(99),
        },
        Message::SessionClose {
            iter: 6,
            beta: f64s(rng, d),
        },
        Message::SessionClose {
            iter: 7,
            beta: vec![],
        },
        Message::CloseAck {
            node: rng.next_u64() as u16,
            is_center: rng.next_bernoulli(0.5),
        },
        Message::Abort {
            reason: format!("abort-{}", rng.next_u64()),
        },
        Message::Abort {
            reason: String::new(),
        },
        Message::NodeError {
            node: rng.next_u64() as u16,
            is_center: rng.next_bernoulli(0.5),
            error: format!("err-{}", rng.next_u64()),
        },
        Message::StudySubmitted,
        Message::AdmissionWake,
        Message::Shutdown,
    ]
}

const SESSIONS: [SessionId; 6] = [CONTROL_SESSION, 1, 2, 4096, u32::MAX - 1, u32::MAX];

#[test]
fn every_variant_roundtrips_under_every_session_id() {
    let mut rng = SplitMix64::new(2024);
    for round in 0..8 {
        for msg in all_variants(&mut rng) {
            for session in SESSIONS {
                let frame = encode_frame(session, &msg);
                let (s, back) = decode_frame(&frame).unwrap();
                assert_eq!(s, session, "round {round}");
                assert_eq!(back, msg, "round {round} session {session}");
            }
        }
    }
}

#[test]
fn frame_is_exactly_header_plus_body() {
    let mut rng = SplitMix64::new(7);
    for msg in all_variants(&mut rng) {
        let body = encode(&msg);
        for session in SESSIONS {
            let frame = encode_frame(session, &msg);
            assert_eq!(frame.len(), SESSION_HEADER_LEN + body.len());
            assert_eq!(&frame[..SESSION_HEADER_LEN], session.to_le_bytes());
            assert_eq!(&frame[SESSION_HEADER_LEN..], &body[..]);
            // the body alone still decodes with the plain codec
            assert_eq!(decode(&frame[SESSION_HEADER_LEN..]).unwrap(), msg);
        }
    }
}

#[test]
fn truncated_frames_are_rejected_at_every_length() {
    let mut rng = SplitMix64::new(99);
    for msg in all_variants(&mut rng) {
        let frame = encode_frame(3, &msg);
        for cut in 0..frame.len() {
            assert!(
                decode_frame(&frame[..cut]).is_err(),
                "cut at {cut}/{} must fail for {}",
                frame.len(),
                msg.kind()
            );
        }
        // ... and the full frame still decodes.
        assert!(decode_frame(&frame).is_ok());
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    let mut rng = SplitMix64::new(5);
    for msg in all_variants(&mut rng) {
        let mut frame = encode_frame(1, &msg);
        frame.push(0);
        assert!(decode_frame(&frame).is_err(), "{}", msg.kind());
    }
}

/// The zero-copy submission encoder must be byte-identical to the
/// Message-based codec for every payload shape and session id class —
/// this equality is what lets the institution hot path skip the owned
/// `Vec` copies without any risk to decoding or traffic accounting.
#[test]
fn zero_copy_submission_encoder_matches_message_codec() {
    let mut rng = SplitMix64::new(4242);
    for _ in 0..16 {
        let d = 1 + (rng.next_u64() % 16) as usize;
        let packed = d * (d + 1) / 2;
        let g: Vec<Fp> = (0..d).map(|_| Fp::new(rng.next_u64())).collect();
        let dev = Fp::new(rng.next_u64());
        let iter = rng.next_u64() as u32;
        let institution = rng.next_u64() as u16;
        let h_plain: Vec<f64> = (0..packed).map(|_| rng.next_gaussian()).collect();
        let h_shared: Vec<Fp> = (0..packed).map(|_| Fp::new(rng.next_u64())).collect();
        for session in SESSIONS {
            let cases: Vec<(HessianRef, HessianPayload)> = vec![
                (
                    HessianRef::Plain(&h_plain),
                    HessianPayload::Plain(h_plain.clone()),
                ),
                (
                    HessianRef::Shared(&h_shared),
                    HessianPayload::Shared(h_shared.clone()),
                ),
                (HessianRef::Absent, HessianPayload::Absent),
            ];
            for (href, hpay) in cases {
                let fast = encode_share_submission(session, iter, institution, href, &g, dev);
                let slow = encode_frame(
                    session,
                    &Message::ShareSubmission {
                        iter,
                        institution,
                        hessian: hpay,
                        g_share: g.clone(),
                        dev_share: dev,
                    },
                );
                assert_eq!(fast, slow, "session {session} d={d}");
            }
        }
    }
}

/// Decode-hardening fuzz gate: `decode_frame` is the first thing that
/// touches bytes arriving off a real socket (`net.rs` link loop), so it
/// must hold up against arbitrary input. The `Result<_, CodecError>`
/// return type already guarantees rejections are *typed*; these tests
/// prove the other two properties — no panic, and no allocation driven
/// by a hostile length prefix beyond the actual buffer size (the
/// `check_len` guard in `Reader::f64s`/`fps`).
#[test]
fn arbitrary_byte_strings_never_panic() {
    let mut rng = SplitMix64::new(0xF00D);
    for _ in 0..4096 {
        let len = (rng.next_u64() % 512) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // Must return Ok or a typed CodecError — never panic, never hang.
        let _ = decode_frame(&bytes);
        let _ = decode(&bytes);
    }
}

#[test]
fn bit_flipped_frames_never_panic_and_truncations_stay_typed() {
    let mut rng = SplitMix64::new(0xBEEF);
    for msg in all_variants(&mut rng) {
        let frame = encode_frame(9, &msg);
        // Flip every bit of the header and tag, then a random sample of
        // payload bits — exhaustive over the region that steers control
        // flow, sampled over the region that only carries data.
        let dense = (SESSION_HEADER_LEN + 1).min(frame.len());
        for byte in 0..dense {
            for bit in 0..8 {
                let mut bad = frame.clone();
                bad[byte] ^= 1 << bit;
                let _ = decode_frame(&bad);
            }
        }
        for _ in 0..256 {
            let mut bad = frame.clone();
            let byte = (rng.next_u64() as usize) % bad.len();
            bad[byte] ^= 1 << (rng.next_u64() % 8);
            let _ = decode_frame(&bad);
            // ... and a truncated prefix of the corrupted frame.
            let cut = (rng.next_u64() as usize) % (bad.len() + 1);
            let _ = decode_frame(&bad[..cut]);
        }
    }
}

/// A hostile length prefix inside the body (e.g. a vector count of
/// u32::MAX followed by no data) must be rejected as `Truncated`
/// *before* any proportional allocation happens. If the guard ever
/// regressed to `Vec::with_capacity(claimed)`, this test would attempt
/// a ~32 GiB allocation and the suite would OOM instead of passing.
#[test]
fn hostile_vector_length_prefixes_are_rejected_without_allocation() {
    let tags: Vec<u8> = {
        let mut rng = SplitMix64::new(11);
        all_variants(&mut rng)
            .iter()
            .map(|m| encode(m)[0])
            .collect()
    };
    for tag in tags {
        // session header + tag + a u32 field (iter/node slot for most
        // variants) + a claimed element count of u32::MAX, then nothing.
        let mut frame = Vec::new();
        frame.extend_from_slice(&3u32.to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&frame);
        assert!(err.is_err(), "tag {tag} accepted a hostile length prefix");
    }
}

#[test]
fn out_of_range_field_elements_are_rejected_in_frames() {
    let msg = Message::ShareSubmission {
        iter: 0,
        institution: 0,
        hessian: HessianPayload::Absent,
        g_share: vec![Fp::new(5)],
        dev_share: Fp::new(6),
    };
    let mut frame = encode_frame(2, &msg);
    let n = frame.len();
    // dev_share is the trailing 8 bytes; overwrite with u64::MAX (≥ P).
    frame[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(decode_frame(&frame).is_err());
}
