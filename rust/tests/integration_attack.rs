//! End-to-end privacy validation: the attacks that motivate the paper
//! succeed against the unprotected baselines and fail against the
//! secure protocol — measured, not asserted by fiat.

use privlr::attack::*;
use privlr::baseline::{datashield_fit, obfuscated_exchange};
use privlr::config::ExperimentConfig;
use privlr::coordinator::secure_fit;
use privlr::data::synthetic;
use privlr::fixed::FixedCodec;
use privlr::shamir::{share_batch, ShamirParams};
use privlr::util::rng::ChaCha20Rng;

/// The full pipeline leak→attack on the DataSHIELD baseline, across
/// every institution and iteration of a real fit.
#[test]
fn plaintext_protocol_leaks_responses_at_every_iteration() {
    let mut ds = synthetic("wide", 40, 10, 5, 0.0, 1.0, 201);
    ds.partition(5); // 8 rows per site < d=10
    let (_, leaks) = datashield_fit(&ds, 1.0, 1e-10, 3).unwrap();
    assert!(!leaks.is_empty());
    for leak in &leaks {
        let (x, y) = ds.shard_data(leak.institution);
        let acc = response_recovery_accuracy(leak, &x, &y).unwrap();
        assert!(
            acc > 0.99,
            "iteration {} institution {}: attack accuracy {acc}",
            leak.iter,
            leak.institution
        );
    }
}

/// The obfuscation baseline fails under collusion for every topology.
#[test]
fn obfuscation_collusion_across_topologies() {
    for s in [2usize, 4, 8] {
        let ds = synthetic("t", 400, 5, s, 0.0, 1.0, 202);
        let ex = obfuscated_exchange(&ds, &[0.1, 0.0, -0.1, 0.2, 0.0], 7);
        let out = collusion_recovers_obfuscated_summaries(&ex);
        assert!(out.recovery_rate > 0.99, "s={s}: {out:?}");
    }
}

/// Below-threshold secrecy holds for several (t, w) and both tiny and
/// huge secrets.
#[test]
fn shamir_secrecy_across_parameters() {
    let mut rng = ChaCha20Rng::seed_from_u64(203);
    for (t, w) in [(2usize, 3usize), (3, 5), (5, 9)] {
        let params = ShamirParams::new(t, w).unwrap();
        let out = below_threshold_views_are_uniform(params, 10_000, &mut rng);
        assert!(out.mean_abs_error < 0.03, "(t={t},w={w}): {out:?}");
        for secret in [0u64, 1, privlr::field::P - 1] {
            let chi = share_marginal_chi_square(
                params,
                privlr::field::Fp::new(secret),
                8_000,
                &mut rng,
            );
            assert!(chi < 80.0, "(t={t},w={w},m={secret}): chi² {chi}");
        }
    }
}

/// The *joint* view of t−1 centers still reconstructs to garbage when
/// they try every possible collusion strategy available to them
/// (interpolating with a guessed share).
#[test]
fn colluding_below_threshold_centers_cannot_reconstruct() {
    let params = ShamirParams::new(3, 5).unwrap();
    let codec = FixedCodec::default();
    let mut rng = ChaCha20Rng::seed_from_u64(204);
    let secret_val = 1234.5678;
    let enc = codec.encode(secret_val).unwrap();
    let batch = share_batch(params, &[enc], &mut rng);
    // Centers 0 and 1 collude; they guess center 2's share at random
    // k times and see how close their best reconstruction gets.
    let mut best = f64::INFINITY;
    for _ in 0..2000 {
        let guess = privlr::field::Fp::random(&mut rng);
        let shares: Vec<(usize, Vec<privlr::field::Fp>)> = vec![
            (0, batch.per_holder[0].clone()),
            (1, batch.per_holder[1].clone()),
            (2, vec![guess]),
        ];
        let refs: Vec<(usize, &[privlr::field::Fp])> =
            shares.iter().map(|(j, v)| (*j, v.as_slice())).collect();
        let rec = codec.decode(privlr::shamir::reconstruct_batch(params, &refs).unwrap()[0]);
        best = best.min((rec - secret_val).abs());
    }
    // 2000 uniform guesses over a 2^61 space: nothing lands anywhere
    // near the secret.
    assert!(best > 1.0, "colluders should learn nothing, best err {best}");
}

/// The secure protocol's actual message stream contains no plaintext
/// gradient: run a fit and verify every gradient payload decodes to
/// garbage for a single center while the fit still matches gold.
#[test]
fn secure_fit_leaks_nothing_but_still_fits() {
    let ds = synthetic("t", 900, 6, 4, 0.0, 1.0, 205);
    let cfg = ExperimentConfig {
        max_iters: 40,
        ..Default::default()
    };
    let fit = secure_fit(&ds, &cfg).unwrap();
    let gold = privlr::baseline::centralized_fit(&ds, cfg.lambda, cfg.tol, 40).unwrap();
    for (a, b) in fit.beta.iter().zip(&gold.beta) {
        assert!((a - b).abs() < 1e-5);
    }
    // Decoding a single share of the true gradient is useless:
    let codec = FixedCodec::default();
    let params = ShamirParams::new(cfg.threshold, cfg.num_centers).unwrap();
    let (x0, y0) = ds.shard_data(0);
    let g0 = privlr::model::local_stats(&x0, &y0, &fit.beta).g;
    let mut rng = ChaCha20Rng::seed_from_u64(206);
    let err = center_view_gradient_error(params, &codec, &g0, &mut rng);
    assert!(err > 1e6, "single-center view must be uninformative: {err}");
}

/// Attack 4 and its closure, end-to-end through the real protocol: the
/// *released* β̂ of a wide consortium (n ≤ d) pins down every private
/// response bit via the stationarity condition — secret sharing cannot
/// help, because the leak is through the agreed output. The same fit
/// with the DP release layer enabled reduces the attacker to chance,
/// ships the mechanism parameters in the result, and withholds the
/// Fisher block.
#[test]
fn released_beta_attack_closed_by_dp_release() {
    let ds = synthetic("wide", 10, 12, 2, 0.0, 1.0, 207);
    let cfg = ExperimentConfig {
        max_iters: 60,
        lambda: 1.0,
        ..Default::default()
    };

    // Without DP the exact coefficients are published and the gram
    // solve reads the response bits straight off.
    let fit = secure_fit(&ds, &cfg).unwrap();
    assert!(fit.dp.is_none(), "DP off must report no release params");
    assert!(fit.fisher.is_some(), "plain fit keeps its Fisher block");
    let acc = released_beta_attack_accuracy(&fit.beta, &ds.x, cfg.lambda, &ds.y).unwrap();
    assert!(acc >= 0.9, "plain release must leak responses: acc {acc}");

    // With DP: identical Newton trajectory, then one joint noise round.
    let mut dp_cfg = cfg.clone();
    dp_cfg.dp = Some(privlr::dp::DpConfig::default());
    let fit_dp = secure_fit(&ds, &dp_cfg).unwrap();
    let params = fit_dp.dp.expect("DP fit must report its release params");
    assert_eq!(params.epsilon, 1.0);
    assert_eq!(params.num_partials, 2, "one partial noise term per institution");
    assert_eq!(
        params.num_honest, 1,
        "default threat model: the guarantee survives all-but-one collusion"
    );
    // sensitivity is 2·clip/λ of the SUMMED objective = 2·1/1
    assert!((params.sensitivity - 2.0).abs() < 1e-12, "Δ₂ {}", params.sensitivity);
    assert!(
        fit_dp.fisher.is_none(),
        "a DP release must not ship the exact Fisher information"
    );
    // The coordinator really did add noise: at ε=1, δ=1e-6 the
    // analytically calibrated σ ≈ 8.45 (and each institution alone
    // supplies the full σ under min_honest = 1), so the released
    // vector moves far from the non-private optimum.
    let max_diff = fit
        .beta
        .iter()
        .zip(&fit_dp.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff > 1e-2, "DP β̂ must differ from the plain β̂: {max_diff}");
    let acc_dp = released_beta_attack_accuracy(&fit_dp.beta, &ds.x, cfg.lambda, &ds.y).unwrap();
    assert!(
        acc_dp <= 0.5,
        "DP release must close the attack to ≤ chance: acc {acc_dp} (plain was {acc})"
    );
}
