//! Protocol-level integration: worker state machines, failure
//! handling, message-flow invariants, and traffic accounting across
//! the full institution ↔ center ↔ coordinator topology, driven by
//! hand over session-tagged frames.

use privlr::center::{run_center_worker, CenterWorkerConfig};
use privlr::field::Fp;
use privlr::fixed::FixedCodec;
use privlr::institution::{run_institution_worker, InstitutionWorkerConfig};
use privlr::linalg::Matrix;
use privlr::protocol::{HessianPayload, Message, NodeId, SessionId};
use privlr::runtime::ComputeHandle;
use privlr::session::{SessionRegistry, SessionSpec, ShardData};
use std::sync::atomic::AtomicUsize;
use privlr::shamir::{reconstruct_batch, ShamirParams};
use privlr::transport::Network;
use privlr::util::rng::{Rng, SplitMix64};
use std::sync::Arc;

fn shard(n: usize, d: usize, seed: u64) -> Arc<ShardData> {
    let mut rng = SplitMix64::new(seed);
    let mut x = Matrix::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            x[(i, j)] = rng.next_gaussian();
        }
        y[i] = f64::from(rng.next_bernoulli(0.45));
    }
    Arc::new(ShardData { x, y })
}

fn make_spec(
    session: SessionId,
    shards: Vec<Arc<ShardData>>,
    t: usize,
    w: usize,
) -> Arc<SessionSpec> {
    Arc::new(SessionSpec::new(
        session,
        shards,
        ShamirParams::new(t, w).unwrap(),
        FixedCodec::default(),
        false,
        1,
        privlr::simd::Isa::Scalar,
        1000,
    ))
}

/// A full manual round: 3 institutions × 5 centers, coordinator drives
/// by hand (session 1) and verifies the reconstructed aggregates
/// against plaintext.
#[test]
fn manual_round_reconstructs_exact_aggregates() {
    let s = 3usize;
    let w = 5usize;
    let t = 3usize;
    let d = 4usize;
    let session: SessionId = 1;
    let params = ShamirParams::new(t, w).unwrap();
    let codec = FixedCodec::default();
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);

    let shards: Vec<Arc<ShardData>> = (0..s).map(|j| shard(40 + j * 10, d, j as u64)).collect();
    let registry = SessionRegistry::new();
    registry.insert(make_spec(session, shards.clone(), t, w));

    let mut center_joins = Vec::new();
    for c in 0..w {
        let ep = net.register(NodeId::Center(c as u16));
        let cfg = CenterWorkerConfig {
            center_id: c as u16,
            registry: registry.clone(),
            live_sessions: Arc::new(AtomicUsize::new(0)),
        };
        center_joins.push(std::thread::spawn(move || run_center_worker(cfg, ep)));
    }
    let mut inst_joins = Vec::new();
    for j in 0..s {
        let ep = net.register(NodeId::Institution(j as u16));
        let cfg = InstitutionWorkerConfig {
            institution_id: j as u16,
            registry: registry.clone(),
            engine: ComputeHandle::rust(),
            live_sessions: Arc::new(AtomicUsize::new(0)),
        };
        inst_joins.push(std::thread::spawn(move || run_institution_worker(cfg, ep)));
    }

    let beta = vec![0.05, -0.1, 0.2, 0.0];
    for j in 0..s {
        coord
            .send_session(
                NodeId::Institution(j as u16),
                session,
                &Message::BetaBroadcast { iter: 0, beta: beta.clone() },
            )
            .unwrap();
    }
    for c in 0..w {
        coord
            .send_session(
                NodeId::Center(c as u16),
                session,
                &Message::AggregateRequest { iter: 0, expected: s as u16 },
            )
            .unwrap();
    }
    let mut responses = Vec::new();
    while responses.len() < w {
        let (_, rsession, msg) = coord.recv_session().unwrap();
        assert_eq!(rsession, session);
        if let Message::AggregateResponse { center, hessian, g_share, dev_share, .. } = msg {
            responses.push((center as usize, hessian, g_share, dev_share));
        }
    }
    responses.sort_by_key(|(c, ..)| *c);

    // Plaintext expectation.
    let mut expect = privlr::model::LocalStats::zeros(d);
    for sh in &shards {
        expect.merge(&privlr::model::local_stats(&sh.x, &sh.y, &beta));
    }

    // Gradient via any t centers.
    let g_quorum: Vec<(usize, &[Fp])> = responses[..t]
        .iter()
        .map(|(c, _, g, _)| (*c, g.as_slice()))
        .collect();
    let g = codec.decode_slice(&reconstruct_batch(params, &g_quorum).unwrap());
    for (a, b) in g.iter().zip(&expect.g) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
    // Deviance likewise; use the LAST t centers to prove any quorum works.
    let dev_quorum: Vec<(usize, Fp)> = responses[w - t..]
        .iter()
        .map(|(c, _, _, dv)| (*c, *dv))
        .collect();
    let dev = codec.decode(privlr::shamir::reconstruct_scalar(params, &dev_quorum).unwrap());
    assert!((dev - expect.dev).abs() < 1e-6);
    // Hessian from the lead center's plaintext.
    let h = match &responses[0].1 {
        HessianPayload::Plain(p) => privlr::protocol::unpack_upper(p, d),
        other => panic!("lead center should answer Plain, got {other:?}"),
    };
    assert!(h.max_abs_diff(&expect.h) < 1e-9);

    // Teardown.
    for j in 0..s {
        coord
            .send(NodeId::Institution(j as u16), &Message::Shutdown)
            .unwrap();
    }
    for c in 0..w {
        coord.send(NodeId::Center(c as u16), &Message::Shutdown).unwrap();
    }
    for h in inst_joins {
        h.join().unwrap().unwrap();
    }
    for h in center_joins {
        h.join().unwrap().unwrap();
    }
}

/// Failure injection: an institution that sends a malformed (wrong-d)
/// submission makes the center report a session-tagged NodeError
/// instead of corrupting state — and the worker survives to serve
/// other sessions.
#[test]
fn center_rejects_malformed_submission() {
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);
    let inst = net.register(NodeId::Institution(0));
    let cep = net.register(NodeId::Center(0));
    let registry = SessionRegistry::new();
    registry.insert(make_spec(2, vec![shard(10, 4, 0)], 1, 1));
    let cfg = CenterWorkerConfig {
        center_id: 0,
        registry,
        live_sessions: Arc::new(AtomicUsize::new(0)),
    };
    let join = std::thread::spawn(move || run_center_worker(cfg, cep));
    // gradient share has d=2, session 2 expects d=4
    inst.send_session(
        NodeId::Center(0),
        2,
        &Message::ShareSubmission {
            iter: 0,
            institution: 0,
            hessian: HessianPayload::Plain(vec![0.0; 10]),
            g_share: vec![Fp::ZERO; 2],
            dev_share: Fp::ZERO,
        },
    )
    .unwrap();
    let (_, session, msg) = coord.recv_session().unwrap();
    assert_eq!(session, 2);
    assert!(
        matches!(msg, Message::NodeError { node: 0, is_center: true, .. }),
        "center must reject the malformed submission"
    );
    coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
    join.join().unwrap().unwrap();
}

/// Failure injection: broadcasts from a node impersonating the
/// coordinator are rejected by institutions (NodeError for that
/// session; worker stays up).
#[test]
fn institution_rejects_non_coordinator_broadcast() {
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);
    let rogue = net.register(NodeId::Institution(9));
    let iep = net.register(NodeId::Institution(0));
    let registry = SessionRegistry::new();
    registry.insert(make_spec(1, vec![shard(10, 3, 5)], 1, 1));
    let cfg = InstitutionWorkerConfig {
        institution_id: 0,
        registry,
        engine: ComputeHandle::rust(),
        live_sessions: Arc::new(AtomicUsize::new(0)),
    };
    let join = std::thread::spawn(move || run_institution_worker(cfg, iep));
    rogue
        .send_session(
            NodeId::Institution(0),
            1,
            &Message::BetaBroadcast { iter: 0, beta: vec![0.0; 3] },
        )
        .unwrap();
    let (_, session, msg) = coord.recv_session().unwrap();
    assert_eq!(session, 1);
    assert!(matches!(msg, Message::NodeError { node: 0, is_center: false, .. }));
    coord.send(NodeId::Institution(0), &Message::Shutdown).unwrap();
    join.join().unwrap().unwrap();
}

/// A center never responds before all expected submissions arrive, even
/// under interleaved iterations.
#[test]
fn center_withholds_partial_aggregates() {
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);
    let inst = net.register(NodeId::Institution(0));
    let cep = net.register(NodeId::Center(0));
    let registry = SessionRegistry::new();
    registry.insert(make_spec(6, vec![shard(5, 1, 0), shard(5, 1, 1)], 1, 1));
    let cfg = CenterWorkerConfig {
        center_id: 0,
        registry,
        live_sessions: Arc::new(AtomicUsize::new(0)),
    };
    let join = std::thread::spawn(move || run_center_worker(cfg, cep));

    coord
        .send_session(
            NodeId::Center(0),
            6,
            &Message::AggregateRequest { iter: 0, expected: 2 },
        )
        .unwrap();
    inst.send_session(
        NodeId::Center(0),
        6,
        &Message::ShareSubmission {
            iter: 0,
            institution: 0,
            hessian: HessianPayload::Plain(vec![1.0]),
            g_share: vec![Fp::new(5)],
            dev_share: Fp::new(6),
        },
    )
    .unwrap();
    // only 1 of 2 expected submissions: no response
    assert!(coord
        .recv_timeout(std::time::Duration::from_millis(80))
        .unwrap()
        .is_none());
    // second submission (different institution id is fine from same ep)
    inst.send_session(
        NodeId::Center(0),
        6,
        &Message::ShareSubmission {
            iter: 0,
            institution: 1,
            hessian: HessianPayload::Plain(vec![2.0]),
            g_share: vec![Fp::new(7)],
            dev_share: Fp::new(8),
        },
    )
    .unwrap();
    let (_, _, msg) = coord.recv_session().unwrap();
    assert!(matches!(msg, Message::AggregateResponse { .. }));
    coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
    join.join().unwrap().unwrap();
}

/// Byte accounting: every frame that crossed a link is counted, the
/// classifications sum to the total, and per-session attribution
/// covers every byte.
#[test]
fn traffic_accounting_is_complete() {
    let ds = privlr::data::synthetic("t", 500, 4, 3, 0.0, 1.0, 9);
    let cfg = privlr::config::ExperimentConfig {
        max_iters: 30,
        ..Default::default()
    };
    let fit = privlr::coordinator::secure_fit(&ds, &cfg).unwrap();
    let tr = fit.metrics.traffic;
    // The four classes partition every byte exactly: the paper's three
    // protocol classes plus the control class (client-injected frames).
    assert_eq!(
        tr.total_bytes,
        tr.submission_bytes + tr.central_bytes + tr.broadcast_bytes + tr.control_bytes,
        "all links must be classified"
    );
    assert!(
        tr.control_bytes > 0,
        "the StudySubmitted nudge and client Shutdown ride the control class"
    );
    // message count: 1 StudySubmitted nudge; per iter: S broadcasts +
    // S·w submissions + w requests + w responses; acknowledged teardown
    // of the session: (S+w) SessionClose + (S+w) CloseAck; engine
    // shutdown: 1 client Shutdown to the (single) driver shard +
    // (S+w) worker shutdowns.
    let (s, w) = (3u64, 5u64);
    let iters = fit.metrics.iterations as u64;
    let expected = iters * (s + s * w + w + w) + 3 * (s + w) + 2;
    assert_eq!(tr.total_messages, expected);
    // per-session totals (study session + control session) sum exactly
    let session_sum: u64 = tr.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(session_sum, tr.total_bytes);
}

/// Regression: a dataset whose shape has NO artifact bucket must not
/// deadlock the coordinator — Auto falls back to rust, and a forced
/// PJRT run aborts with a NodeError instead of hanging.
#[test]
fn missing_bucket_aborts_instead_of_deadlocking() {
    // d=13 has no artifact; bucket check at Auto level falls back.
    let ds = privlr::data::synthetic("t", 200, 13, 2, 0.0, 1.0, 55);
    let auto_cfg = privlr::config::ExperimentConfig {
        engine: privlr::config::EngineKind::Auto,
        max_iters: 20,
        ..Default::default()
    };
    let fit = privlr::coordinator::secure_fit(&ds, &auto_cfg).unwrap();
    assert!(fit.metrics.iterations > 0);

    // Forced PJRT with artifacts present but no matching bucket: the
    // institution errors, the coordinator must return Err promptly.
    if privlr::runtime::Manifest::load(std::path::Path::new("artifacts")).is_ok() {
        let pjrt_cfg = privlr::config::ExperimentConfig {
            engine: privlr::config::EngineKind::Pjrt,
            max_iters: 20,
            ..Default::default()
        };
        let start = std::time::Instant::now();
        let out = privlr::coordinator::secure_fit(&ds, &pjrt_cfg);
        assert!(out.is_err(), "must abort, not hang");
        let msg = out.unwrap_err().to_string();
        assert!(msg.contains("failed"), "{msg}");
        assert!(start.elapsed().as_secs() < 30, "abort should be prompt");
    }
}
