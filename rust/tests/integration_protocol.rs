//! Protocol-level integration: node state machines, failure handling,
//! message-flow invariants, and traffic accounting across the full
//! institution ↔ center ↔ coordinator topology.

use privlr::center::{run_center, CenterConfig};
use privlr::field::Fp;
use privlr::fixed::FixedCodec;
use privlr::institution::{run_institution, InstitutionConfig};
use privlr::linalg::Matrix;
use privlr::protocol::{HessianPayload, Message, NodeId};
use privlr::runtime::ComputeHandle;
use privlr::shamir::{reconstruct_batch, ShamirParams};
use privlr::transport::Network;
use privlr::util::rng::{Rng, SplitMix64};

fn shard(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let mut x = Matrix::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            x[(i, j)] = rng.next_gaussian();
        }
        y[i] = f64::from(rng.next_bernoulli(0.45));
    }
    (x, y)
}

/// A full manual round: 3 institutions × 5 centers, coordinator drives
/// by hand and verifies the reconstructed aggregates against plaintext.
#[test]
fn manual_round_reconstructs_exact_aggregates() {
    let s = 3usize;
    let w = 5usize;
    let t = 3usize;
    let d = 4usize;
    let params = ShamirParams::new(t, w).unwrap();
    let codec = FixedCodec::default();
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);

    let mut center_joins = Vec::new();
    for c in 0..w {
        let ep = net.register(NodeId::Center(c as u16));
        let cfg = CenterConfig::new(c as u16, d, false);
        center_joins.push(std::thread::spawn(move || run_center(cfg, ep)));
    }
    let mut shards = Vec::new();
    let mut inst_joins = Vec::new();
    for j in 0..s {
        let (x, y) = shard(40 + j * 10, d, j as u64);
        shards.push((x.clone(), y.clone()));
        let ep = net.register(NodeId::Institution(j as u16));
        let cfg = InstitutionConfig {
            institution_id: j as u16,
            x,
            y,
            params,
            codec,
            full_security: false,
            engine: ComputeHandle::rust(),
            share_seed: 1000 + j as u64,
            kernel_threads: 1,
        };
        inst_joins.push(std::thread::spawn(move || run_institution(cfg, ep)));
    }

    let beta = vec![0.05, -0.1, 0.2, 0.0];
    for j in 0..s {
        coord
            .send(
                NodeId::Institution(j as u16),
                &Message::BetaBroadcast { iter: 0, beta: beta.clone() },
            )
            .unwrap();
    }
    for c in 0..w {
        coord
            .send(
                NodeId::Center(c as u16),
                &Message::AggregateRequest { iter: 0, expected: s as u16 },
            )
            .unwrap();
    }
    let mut responses = Vec::new();
    while responses.len() < w {
        let (_, msg) = coord.recv().unwrap();
        if let Message::AggregateResponse { center, hessian, g_share, dev_share, .. } = msg {
            responses.push((center as usize, hessian, g_share, dev_share));
        }
    }
    responses.sort_by_key(|(c, ..)| *c);

    // Plaintext expectation.
    let mut expect = privlr::model::LocalStats::zeros(d);
    for (x, y) in &shards {
        expect.merge(&privlr::model::local_stats(x, y, &beta));
    }

    // Gradient via any t centers.
    let g_quorum: Vec<(usize, &[Fp])> = responses[..t]
        .iter()
        .map(|(c, _, g, _)| (*c, g.as_slice()))
        .collect();
    let g = codec.decode_slice(&reconstruct_batch(params, &g_quorum).unwrap());
    for (a, b) in g.iter().zip(&expect.g) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
    // Deviance likewise; use the LAST t centers to prove any quorum works.
    let dev_quorum: Vec<(usize, Fp)> = responses[w - t..]
        .iter()
        .map(|(c, _, _, dv)| (*c, *dv))
        .collect();
    let dev = codec.decode(privlr::shamir::reconstruct_scalar(params, &dev_quorum).unwrap());
    assert!((dev - expect.dev).abs() < 1e-6);
    // Hessian from the lead center's plaintext.
    let h = match &responses[0].1 {
        HessianPayload::Plain(p) => privlr::protocol::unpack_upper(p, d),
        other => panic!("lead center should answer Plain, got {other:?}"),
    };
    assert!(h.max_abs_diff(&expect.h) < 1e-9);

    // Teardown.
    for j in 0..s {
        coord
            .send(NodeId::Institution(j as u16), &Message::Shutdown)
            .unwrap();
    }
    for c in 0..w {
        coord.send(NodeId::Center(c as u16), &Message::Shutdown).unwrap();
    }
    for h in inst_joins {
        h.join().unwrap().unwrap();
    }
    for h in center_joins {
        h.join().unwrap().unwrap();
    }
}

/// Failure injection: an institution that sends a malformed (wrong-d)
/// submission makes the center error out rather than corrupt state.
#[test]
fn center_rejects_malformed_submission() {
    let net = Network::new();
    let _coord = net.register(NodeId::Coordinator);
    let inst = net.register(NodeId::Institution(0));
    let cep = net.register(NodeId::Center(0));
    let cfg = CenterConfig::new(0, 4, false);
    let join = std::thread::spawn(move || run_center(cfg, cep));
    // gradient share has d=2, center expects d=4
    inst.send(
        NodeId::Center(0),
        &Message::ShareSubmission {
            iter: 0,
            institution: 0,
            hessian: HessianPayload::Plain(vec![0.0; 10]),
            g_share: vec![Fp::ZERO; 2],
            dev_share: Fp::ZERO,
        },
    )
    .unwrap();
    let out = join.join().unwrap();
    assert!(out.is_err(), "center must reject the malformed submission");
}

/// Failure injection: submissions from a node impersonating the
/// coordinator are rejected by institutions.
#[test]
fn institution_rejects_non_coordinator_broadcast() {
    let net = Network::new();
    let rogue = net.register(NodeId::Institution(9));
    let iep = net.register(NodeId::Institution(0));
    let (x, y) = shard(10, 3, 5);
    let cfg = InstitutionConfig {
        institution_id: 0,
        x,
        y,
        params: ShamirParams::new(1, 1).unwrap(),
        codec: FixedCodec::default(),
        full_security: false,
        engine: ComputeHandle::rust(),
        share_seed: 3,
        kernel_threads: 1,
    };
    let join = std::thread::spawn(move || run_institution(cfg, iep));
    rogue
        .send(
            NodeId::Institution(0),
            &Message::BetaBroadcast { iter: 0, beta: vec![0.0; 3] },
        )
        .unwrap();
    assert!(join.join().unwrap().is_err());
}

/// A center never responds before all expected submissions arrive, even
/// under interleaved iterations.
#[test]
fn center_withholds_partial_aggregates() {
    let net = Network::new();
    let coord = net.register(NodeId::Coordinator);
    let inst = net.register(NodeId::Institution(0));
    let cep = net.register(NodeId::Center(0));
    let cfg = CenterConfig::new(0, 1, false);
    let join = std::thread::spawn(move || run_center(cfg, cep));

    coord
        .send(
            NodeId::Center(0),
            &Message::AggregateRequest { iter: 0, expected: 2 },
        )
        .unwrap();
    inst.send(
        NodeId::Center(0),
        &Message::ShareSubmission {
            iter: 0,
            institution: 0,
            hessian: HessianPayload::Plain(vec![1.0]),
            g_share: vec![Fp::new(5)],
            dev_share: Fp::new(6),
        },
    )
    .unwrap();
    // only 1 of 2 expected submissions: no response
    assert!(coord
        .recv_timeout(std::time::Duration::from_millis(80))
        .unwrap()
        .is_none());
    // second submission (different institution id is fine from same ep)
    inst.send(
        NodeId::Center(0),
        &Message::ShareSubmission {
            iter: 0,
            institution: 1,
            hessian: HessianPayload::Plain(vec![2.0]),
            g_share: vec![Fp::new(7)],
            dev_share: Fp::new(8),
        },
    )
    .unwrap();
    let (_, msg) = coord.recv().unwrap();
    assert!(matches!(msg, Message::AggregateResponse { .. }));
    coord.send(NodeId::Center(0), &Message::Shutdown).unwrap();
    join.join().unwrap().unwrap();
}

/// Byte accounting: every message that crossed a link is counted, and
/// the classifications sum to the total.
#[test]
fn traffic_accounting_is_complete() {
    let ds = privlr::data::synthetic("t", 500, 4, 3, 0.0, 1.0, 9);
    let cfg = privlr::config::ExperimentConfig {
        max_iters: 30,
        ..Default::default()
    };
    let fit = privlr::coordinator::secure_fit(&ds, &cfg).unwrap();
    let tr = fit.metrics.traffic;
    assert_eq!(
        tr.total_bytes,
        tr.submission_bytes + tr.central_bytes + tr.broadcast_bytes,
        "all links must be classified"
    );
    // message count: per iter: S broadcasts + S·w submissions + w requests
    // + w responses; plus teardown S finished + w shutdowns.
    let (s, w) = (3u64, 5u64);
    let iters = fit.metrics.iterations as u64;
    let expected = iters * (s + s * w + w + w) + s + w;
    assert_eq!(tr.total_messages, expected);
}

/// Regression: a dataset whose shape has NO artifact bucket must not
/// deadlock the coordinator — Auto falls back to rust, and a forced
/// PJRT run aborts with a NodeError instead of hanging.
#[test]
fn missing_bucket_aborts_instead_of_deadlocking() {
    // d=13 has no artifact; bucket check at Auto level falls back.
    let ds = privlr::data::synthetic("t", 200, 13, 2, 0.0, 1.0, 55);
    let auto_cfg = privlr::config::ExperimentConfig {
        engine: privlr::config::EngineKind::Auto,
        max_iters: 20,
        ..Default::default()
    };
    let fit = privlr::coordinator::secure_fit(&ds, &auto_cfg).unwrap();
    assert!(fit.metrics.iterations > 0);

    // Forced PJRT with artifacts present but no matching bucket: the
    // institution errors, the coordinator must return Err promptly.
    if privlr::runtime::Manifest::load(std::path::Path::new("artifacts")).is_ok() {
        let pjrt_cfg = privlr::config::ExperimentConfig {
            engine: privlr::config::EngineKind::Pjrt,
            max_iters: 20,
            ..Default::default()
        };
        let start = std::time::Instant::now();
        let out = privlr::coordinator::secure_fit(&ds, &pjrt_cfg);
        assert!(out.is_err(), "must abort, not hang");
        let msg = out.unwrap_err().to_string();
        assert!(msg.contains("failed"), "{msg}");
        assert!(start.elapsed().as_secs() < 30, "abort should be prompt");
    }
}
