//! Fault-tolerance gates: crash-fault injection over the in-memory
//! transport, worker restart under the old `NodeId`, suspended-session
//! re-admission with replay, and the deadline timer wheel.
//!
//! The headline invariant is **replay bit-identity**: a fit that loses
//! a worker mid-protocol and recovers through suspend → re-admit →
//! `SessionReopen` → replay produces a β̂ byte-identical to an
//! uninterrupted fit. That holds because every share is a pure
//! function of `(session spec, β, derive_seed(share_seed, iter))`,
//! share-domain folds are exact field arithmetic, and reconstruction
//! from any t-quorum is exact — there is no hidden accumulator state
//! to lose.
//!
//! The chaos gate (`#[ignore]`, run via `PRIVLR_CHAOS=1 ./ci.sh`)
//! re-proves the sharded bit-identity invariant under seeded random
//! duplicate/delay fault plans at N ∈ {1, 2, 4} driver shards.

use privlr::config::{ExperimentConfig, OnExhausted, SecurityMode};
use privlr::data::synthetic;
use privlr::engine::{
    EngineOptions, Lifecycle, RetryPolicy, StudyEngine, SubmitError, SubmitOptions, SubmitPolicy,
};
use privlr::protocol::{NodeId, TAG_AGG_RESP, TAG_BETA, TAG_SUBMIT};
use privlr::transport::{FaultAction, FaultPlan, FaultRule};
use std::time::{Duration, Instant};

fn cfg_3c() -> ExperimentConfig {
    ExperimentConfig {
        num_centers: 3,
        threshold: 2,
        max_iters: 30,
        ..ExperimentConfig::default()
    }
}

/// A config heavy enough that its fit reliably outlives the test
/// thread's kill/submit interleavings (full security: shared Hessian).
fn heavy_cfg() -> ExperimentConfig {
    ExperimentConfig {
        mode: SecurityMode::Full,
        ..cfg_3c()
    }
}

/// Poll the lifecycle board until `sid` reaches `want` (bounded).
fn await_lifecycle(engine: &StudyEngine, sid: u32, want: Lifecycle) {
    let t0 = Instant::now();
    while engine.lifecycle(sid) != Some(want) {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "session {sid} never reached {want:?} (now {:?})",
            engine.lifecycle(sid)
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Every-worker-clean postcondition: gauges zero, no spec distributed.
fn assert_no_leaks(engine: &StudyEngine) {
    assert!(
        engine.worker_live_sessions().iter().all(|&n| n == 0),
        "worker state leaked: {:?}",
        engine.worker_live_sessions()
    );
    assert_eq!(engine.live_specs(), 0, "session specs leaked");
}

/// Kill one worker while a fit is mid-round, restart it, and require
/// the recovered fit to be byte-identical to an uninterrupted one —
/// at every driver-shard count, for an institution AND a center crash.
#[test]
fn mid_fit_worker_crash_recovers_bit_identically_across_shards() {
    let ds = synthetic("crash", 4000, 5, 2, 0.0, 1.0, 701);
    let cfg = heavy_cfg();
    // Uninterrupted baseline (shard count does not move numerics —
    // that is already gated by integration_sessions).
    let clean = StudyEngine::new(2, 3).unwrap();
    let beta_base = clean
        .submit(&cfg, &ds, SubmitOptions::default())
        .unwrap()
        .join()
        .unwrap()
        .beta;
    clean.shutdown().unwrap();

    for (shards, kill_center) in [(1usize, false), (2, true), (4, false)] {
        let engine = StudyEngine::with_options(
            2,
            3,
            EngineOptions {
                driver_shards: shards,
                retry: RetryPolicy {
                    max_retries: 500,
                    backoff: Duration::from_millis(2),
                    on_exhausted: OnExhausted::Abort,
                },
                ..Default::default()
            },
        )
        .unwrap();
        let h = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap();
        let sid = h.session_id();
        await_lifecycle(&engine, sid, Lifecycle::Running);
        if kill_center {
            engine.kill_center(1).unwrap();
            engine.restart_center(1).unwrap();
        } else {
            engine.kill_institution(1).unwrap();
            engine.restart_institution(1).unwrap();
        }
        let fit = h.join().unwrap();
        assert_eq!(
            fit.beta, beta_base,
            "replay after a {} crash must be bit-identical (shards={shards})",
            if kill_center { "center" } else { "institution" }
        );
        assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Closed));
        assert_no_leaks(&engine);
        engine.shutdown().unwrap();
    }
}

/// A dead worker that never comes back exhausts the retry budget: the
/// session resolves `Aborted` through the acknowledged drain, the
/// survivors hold zero per-session state, and — after a restart — the
/// same engine serves studies again.
#[test]
fn exhausted_retry_budget_aborts_cleanly_with_zero_leaks() {
    let ds = synthetic("exhaust", 300, 3, 2, 0.0, 1.0, 702);
    let cfg = cfg_3c();
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions {
            retry: RetryPolicy {
                max_retries: 1,
                backoff: Duration::from_millis(1),
                on_exhausted: OnExhausted::Abort,
            },
            ..Default::default()
        },
    )
    .unwrap();
    engine.kill_institution(0).unwrap();
    let h = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap();
    let sid = h.session_id();
    let err = h.join().unwrap_err();
    assert!(
        err.to_string().contains("retry budget"),
        "expected retry exhaustion, got: {err:#}"
    );
    assert_eq!(engine.lifecycle(sid), Some(Lifecycle::Aborted));
    assert_no_leaks(&engine);
    // Recovery: the restarted worker serves fresh sessions.
    engine.restart_institution(0).unwrap();
    let fit = engine
        .submit(&cfg, &ds, SubmitOptions::default())
        .unwrap()
        .join()
        .unwrap();
    assert!(fit.metrics.iterations > 1);
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();
}

/// Duplicated and delayed frames neither move the numbers nor the
/// byte accounting: a fit under a duplicate/delay plan yields the same
/// β̂ AND the same per-session traffic bytes as a fault-free fit —
/// duplicates are delivered but counted once (center- and driver-side
/// dedup absorbs them), delays only reorder.
#[test]
fn duplicated_and_delayed_frames_neither_corrupt_nor_double_count() {
    let ds = synthetic("dup", 600, 4, 2, 0.0, 1.0, 703);
    let cfg = cfg_3c();
    let engine = StudyEngine::new(2, 3).unwrap();
    let h1 = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap();
    let s1 = h1.session_id();
    let beta_clean = h1.join().unwrap().beta;
    let clean_bytes = engine.traffic().session_bytes(s1);
    assert!(clean_bytes > 0);

    // Duplicate share submissions into a center (its per-(iter,
    // institution) `seen` set must dedup), duplicate aggregate
    // responses back to the driver (its per-center dedup must), and
    // delay β broadcasts to institution 1 by one routed frame
    // (institution 0's independent traffic ticks them free).
    engine.install_faults(
        FaultPlan::new()
            .rule(FaultRule {
                to: Some(NodeId::Center(0)),
                session: None,
                tag: Some(TAG_SUBMIT),
                action: FaultAction::Duplicate,
                budget: 3,
            })
            .rule(FaultRule {
                to: Some(NodeId::Coordinator),
                session: None,
                tag: Some(TAG_AGG_RESP),
                action: FaultAction::Duplicate,
                budget: 3,
            })
            .rule(FaultRule {
                to: Some(NodeId::Institution(1)),
                session: None,
                tag: Some(TAG_BETA),
                action: FaultAction::Delay(1),
                budget: 2,
            }),
    );
    let h2 = engine.submit(&cfg, &ds, SubmitOptions::default()).unwrap();
    let s2 = h2.session_id();
    let beta_faulted = h2.join().unwrap().beta;
    engine.clear_faults();

    assert_eq!(beta_faulted, beta_clean, "duplicates/delays moved the numerics");
    let snap = engine.traffic();
    assert_eq!(
        snap.session_bytes(s2),
        clean_bytes,
        "a duplicated delivery must be counted once"
    );
    let live: u64 = snap.per_session.iter().map(|&(_, b)| b).sum();
    assert_eq!(live + snap.retired_bytes, snap.total_bytes, "traffic invariant");
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();
}

/// DP noise-share frames (TAG 17) cannot double-apply noise. The
/// release round's partial noise is a replay-stable function of the
/// institution's per-session nonce and centers dedup submissions per
/// `(iter, institution)`, so transport-duplicated and delayed noise
/// frames — and even a duplicated noise REQUEST that makes an
/// institution resample and re-send from scratch — leave the released
/// β̂ byte-identical to a fault-free DP fit.
#[test]
fn dp_noise_frames_survive_duplication_and_delay() {
    use privlr::protocol::{TAG_DP_NOISE_REQ, TAG_DP_NOISE_SUB};
    let ds = synthetic("dpfault", 600, 4, 2, 0.0, 1.0, 709);
    let mut cfg = cfg_3c();
    cfg.dp = Some(privlr::dp::DpConfig::default());
    // In a deployment each institution draws its noise nonce from
    // local OS entropy, which would make cross-engine β̂ comparison
    // meaningless; the comparison runs here pin the SAME nonces through
    // the test-only entry point so the byte-identity oracle is exact.
    // They must also land on the same session id (fresh engines assign
    // ids from the same counter; asserted below to keep the premise
    // explicit), since the noise stream is keyed per session.
    let nonces: [u64; 2] = [0xA1A1_0001, 0xB2B2_0002];

    // Fault-free DP baseline.
    let clean = StudyEngine::new(2, 3).unwrap();
    let h = clean
        .submit_with_dp_nonces(&cfg, &ds, SubmitOptions::default(), &nonces)
        .unwrap();
    let sid_clean = h.session_id();
    let fit_clean = h.join().unwrap();
    let clean_bytes = clean.traffic().session_bytes(sid_clean);
    clean.shutdown().unwrap();
    assert!(fit_clean.dp.is_some() && fit_clean.fisher.is_none());

    // Transport-level duplicate + delay of the noise submissions:
    // center 0's per-(iter, institution) `seen` set must absorb the
    // duplicates, center 1's delayed folds must still reach the
    // t-quorum, and the duplicated delivery is counted once.
    let engine = StudyEngine::new(2, 3).unwrap();
    engine.install_faults(
        FaultPlan::new()
            .rule(FaultRule {
                to: Some(NodeId::Center(0)),
                session: None,
                tag: Some(TAG_DP_NOISE_SUB),
                action: FaultAction::Duplicate,
                budget: 3,
            })
            .rule(FaultRule {
                to: Some(NodeId::Center(1)),
                session: None,
                tag: Some(TAG_DP_NOISE_SUB),
                action: FaultAction::Delay(1),
                budget: 2,
            }),
    );
    let h = engine
        .submit_with_dp_nonces(&cfg, &ds, SubmitOptions::default(), &nonces)
        .unwrap();
    assert_eq!(h.session_id(), sid_clean, "session ids must match for seed parity");
    let fit_faulted = h.join().unwrap();
    engine.clear_faults();
    assert_eq!(
        fit_faulted.beta, fit_clean.beta,
        "duplicated/delayed noise shares double-applied noise"
    );
    assert_eq!(
        engine.traffic().session_bytes(sid_clean),
        clean_bytes,
        "a duplicated noise delivery must be counted once"
    );
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();

    // A duplicated noise REQUEST makes institution 1 resample and
    // re-send real frames; replay-stability makes them bit-identical
    // and the center dedup drops them — β̂ unchanged. (Byte accounting
    // legitimately differs here: the re-sent frames are real traffic.)
    let engine = StudyEngine::new(2, 3).unwrap();
    engine.install_faults(FaultPlan::new().rule(FaultRule {
        to: Some(NodeId::Institution(1)),
        session: None,
        tag: Some(TAG_DP_NOISE_REQ),
        action: FaultAction::Duplicate,
        budget: 2,
    }));
    let h = engine
        .submit_with_dp_nonces(&cfg, &ds, SubmitOptions::default(), &nonces)
        .unwrap();
    assert_eq!(h.session_id(), sid_clean, "session ids must match for seed parity");
    let fit_resent = h.join().unwrap();
    engine.clear_faults();
    assert_eq!(
        fit_resent.beta, fit_clean.beta,
        "a re-sent noise round moved the released β̂"
    );
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();
}

/// The deadline timer wheel: a study queued on an otherwise IDLE
/// driver shard (no protocol frames ever reach it — the running study
/// lives on the other shard) must still observe its lapsed deadline
/// promptly. Only the timer wheel's injected `AdmissionWake` can wake
/// that driver, so a prompt typed rejection proves the wheel fires.
#[test]
fn timer_wheel_fires_deadline_on_idle_shard_under_saturated_cap() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 704);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 705);
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, driver_shards: 2, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg(), &ds_heavy, SubmitOptions::bulk()).unwrap();
    let busy_shard = engine.shard_of(h_heavy.session_id());
    await_lifecycle(&engine, h_heavy.session_id(), Lifecycle::Running);
    // Queue short-deadline studies until one lands on the idle shard
    // (session → shard is a hash; a handful of submissions covers both
    // shards with overwhelming probability).
    let mut handles = Vec::new();
    let mut idle_handle = None;
    for _ in 0..16 {
        let h = engine
            .submit(
                &cfg_3c(),
                &ds_light,
                SubmitOptions::default().deadline(Duration::from_millis(60)),
            )
            .unwrap();
        if engine.shard_of(h.session_id()) != busy_shard {
            idle_handle = Some(h);
            break;
        }
        handles.push(h);
    }
    let idle_handle = idle_handle.expect("16 hashed sessions never hit the second shard");
    let t0 = Instant::now();
    let err = idle_handle.join().unwrap_err();
    let waited = t0.elapsed();
    assert!(
        matches!(err.downcast_ref::<SubmitError>(), Some(SubmitError::Deadline { .. })),
        "expected typed Deadline, got: {err:#}"
    );
    // Fired by the wheel shortly after the 60ms deadline — NOT when
    // the heavy study eventually completes and frees the slot.
    assert!(
        waited < Duration::from_secs(2),
        "deadline on the idle shard took {waited:?} — timer wheel never fired"
    );
    // Soundness of the proof: the slot was never released while we
    // waited (peer wakes happen only on slot release), so nothing but
    // the timer's AdmissionWake could have woken the idle driver.
    assert_eq!(
        engine.lifecycle(h_heavy.session_id()),
        Some(Lifecycle::Running),
        "heavy study finished before the deadline fired — timer proof inconclusive"
    );
    for h in handles {
        // Same-shard stragglers also reject at their deadlines.
        let err = h.join().unwrap_err();
        assert!(err.to_string().contains("deadline"), "got: {err:#}");
    }
    h_heavy.join().unwrap();
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();
}

/// `SubmitPolicy::Block` + deadline: a submitter blocked on a full
/// lane is cut loose with the TYPED deadline error — downcastable,
/// carrying the session id and the configured deadline.
#[test]
fn blocked_submitter_observes_typed_deadline_error() {
    let ds_heavy = synthetic("heavy", 6000, 6, 2, 0.0, 1.0, 706);
    let ds_light = synthetic("light", 300, 3, 2, 0.0, 1.0, 707);
    let engine = StudyEngine::with_options(
        2,
        3,
        EngineOptions { max_in_flight: 1, lane_capacity: 1, ..Default::default() },
    )
    .unwrap();
    let h_heavy = engine.submit(&heavy_cfg(), &ds_heavy, SubmitOptions::bulk()).unwrap();
    let h_fill = engine.submit(&cfg_3c(), &ds_light, SubmitOptions::bulk()).unwrap();
    let err = engine
        .submit(
            &cfg_3c(),
            &ds_light,
            SubmitOptions::bulk()
                .policy(SubmitPolicy::Block)
                .deadline(Duration::from_millis(40)),
        )
        .unwrap_err();
    match err.downcast_ref::<SubmitError>() {
        Some(SubmitError::Deadline { session, deadline }) => {
            assert!(*session > 0);
            assert_eq!(*deadline, Duration::from_millis(40));
        }
        other => panic!("expected typed Deadline, got {other:?} ({err:#})"),
    }
    h_heavy.join().unwrap();
    h_fill.join().unwrap();
    assert_no_leaks(&engine);
    engine.shutdown().unwrap();
}

/// Chaos gate (run via `PRIVLR_CHAOS=1 ./ci.sh`): seeded random
/// duplicate/delay fault plans over every link, at N ∈ {1, 2, 4}
/// driver shards — every fit completes and every β̂ stays
/// byte-identical to the fault-free baseline. Liveness-preserving by
/// construction: `seeded_chaos` draws no drops and no coordinator-
/// bound delays.
#[test]
#[ignore = "chaos mode: run via PRIVLR_CHAOS=1 ./ci.sh"]
fn chaos_fault_plans_preserve_sharded_bit_identity() {
    let ds = synthetic("chaos", 800, 4, 2, 0.0, 1.0, 708);
    let cfg = cfg_3c();
    let clean = StudyEngine::new(2, 3).unwrap();
    let beta_base = clean
        .submit(&cfg, &ds, SubmitOptions::default())
        .unwrap()
        .join()
        .unwrap()
        .beta;
    clean.shutdown().unwrap();
    let shards_data = privlr::session::ShardData::split(&ds);
    for shards in [1usize, 2, 4] {
        let engine = StudyEngine::with_options(
            2,
            3,
            EngineOptions { driver_shards: shards, ..Default::default() },
        )
        .unwrap();
        engine.install_faults(FaultPlan::seeded_chaos(
            0xC0FF_EE00 + shards as u64,
            12,
            2,
            3,
        ));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                engine
                    .submit_shared(&cfg, shards_data.clone(), SubmitOptions::default())
                    .unwrap()
            })
            .collect();
        for h in handles {
            let fit = h.join().unwrap();
            assert_eq!(
                fit.beta, beta_base,
                "chaos plan moved the numerics at {shards} shard(s)"
            );
        }
        assert_no_leaks(&engine);
        engine.shutdown().unwrap();
    }
}
