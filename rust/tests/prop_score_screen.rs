//! Acceptance gates for the GWAS score-screen fast path
//! (`model::snp_screen_stats` + `NullModelCache` through the secure
//! share pipeline):
//!
//! * the secure screen statistic — per-institution `[U | b | q]`
//!   summaries Shamir-shared, folded per center, reconstructed and
//!   decoded — is **bit-identical** to the plaintext field reference
//!   (encode → exact field sum in institution order → decode → same
//!   cached factorization), across `kernel_threads ∈ {1, 2, 4}`, ISA
//!   auto and scalar, and lane-straddling covariate dimensions;
//! * the fused per-SNP kernel under the `resolve(Auto)` ISA is
//!   bit-identical to its scalar reference twin;
//! * after warm-up, one per-SNP institution share iteration — fused
//!   score-stats into the pooled summary buffer, encode+share into the
//!   pooled holder buffers — performs **zero heap allocations**,
//!   verified with a counting global allocator, while walking DIFFERENT
//!   SNP columns each iteration (the panel is column-sliced, never
//!   copied).

use privlr::config::KernelIsa;
use privlr::data::synthetic_panel;
use privlr::field::{add_assign_slice, Fp};
use privlr::fixed::FixedCodec;
use privlr::model::{
    local_stats, snp_screen_stats, snp_screen_stats_reference, NullModelCache, ScreenShard,
};
use privlr::secure::{encode_share_into, encode_share_into_isa, ShareContext, SharePool};
use privlr::shamir::{reconstruct_batch, ShamirParams};
use privlr::simd::{resolve, Isa};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

// ---- thread-local allocation counter (mirrors prop_secure_pipeline) -----

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---- helpers ------------------------------------------------------------

/// Panel + null-model fixture at covariate dimension `d`. The null fit
/// is the plaintext damped-Newton reference (what the secure null fit
/// is bit-equal to within codec precision); the cache's Fisher block
/// is the unpenalized information at β̂₀, exactly what the secure
/// fit's final reconstructed aggregate Hessian holds.
fn fixture(d: usize, seed: u64) -> (std::sync::Arc<privlr::data::SnpPanel>, NullModelCache) {
    let panel = synthetic_panel("p", 96, d, 3, 5, 1, 1.0, seed);
    let x = &panel.covariates.x;
    let y = &panel.covariates.y;
    let fit = privlr::model::damped_newton_fit(x, y, 1.0, 1e-10, 50, 20).unwrap();
    let fisher = local_stats(x, y, &fit.beta).h;
    let null = NullModelCache::new(fit.beta, &fisher, 1.0).unwrap();
    (std::sync::Arc::new(panel), null)
}

/// One institution's screen summary `[U | b_0..b_{d-1} | q]` for SNP
/// `s`, through the fused kernel at `isa`.
fn summary_for(
    panel: &privlr::data::SnpPanel,
    null: &NullModelCache,
    s: usize,
    j: usize,
    isa: Isa,
) -> Vec<f64> {
    let sh = &panel.shard_data()[j];
    let scr = ScreenShard::build(&sh.x, &sh.y, &null.beta, isa);
    let d = panel.d();
    let mut summary = vec![0.0; d + 2];
    let (u, q) = {
        let (_, rest) = summary.split_at_mut(1);
        snp_screen_stats(&sh.x, &scr, panel.snp_shard(s, j), isa, &mut rest[..d])
    };
    summary[0] = u;
    summary[d + 1] = q;
    summary
}

/// Gate 1: secure reconstruction of the screen statistic is bitwise
/// the plaintext field reference — encode each institution's summary,
/// exact field sum in institution order, decode, score-test through
/// the same cached factorization. Swept over lane-straddling d,
/// `kernel_threads ∈ {1, 2, 4}`, and ISA scalar/auto.
#[test]
fn secure_screen_statistic_bit_identical_to_field_reference() {
    let params = ShamirParams::new(2, 4).unwrap();
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let auto = resolve(KernelIsa::Auto);
    for d in [1usize, 3, 4, 5, 7, 8] {
        let (panel, null) = fixture(d, 0x5C0_0E00 + d as u64);
        for s in 0..panel.num_snps() {
            for isa in [Isa::Scalar, auto] {
                // Plaintext field reference: exact field sum of the
                // encoded summaries, institution order.
                let mut acc = vec![Fp::ZERO; d + 2];
                for j in 0..panel.num_institutions() {
                    let summary = summary_for(&panel, &null, s, j, isa);
                    let enc = codec.encode_slice(&summary).unwrap();
                    add_assign_slice(&mut acc, &enc);
                }
                let totals = codec.decode_slice(&acc);
                let (ref_chi2, ref_p) =
                    null.score_test(totals[0], &totals[1..=d], totals[d + 1]);
                for threads in [1usize, 2, 4] {
                    // Secure path: share each summary, fold per
                    // center, reconstruct a t-quorum, decode.
                    let mut pool = SharePool::new();
                    let mut center_accs: Vec<Vec<Fp>> =
                        (0..4).map(|_| vec![Fp::ZERO; d + 2]).collect();
                    for j in 0..panel.num_institutions() {
                        let summary = summary_for(&panel, &null, s, j, isa);
                        encode_share_into_isa(
                            &ctx,
                            &codec,
                            &summary,
                            (s * 31 + j) as u64,
                            threads,
                            isa,
                            &mut pool,
                        )
                        .unwrap();
                        for (c, cacc) in center_accs.iter_mut().enumerate() {
                            add_assign_slice(cacc, pool.holder(c));
                        }
                    }
                    let quorum: Vec<(usize, &[Fp])> = [1usize, 3]
                        .iter()
                        .map(|&c| (c, center_accs[c].as_slice()))
                        .collect();
                    let rec = reconstruct_batch(params, &quorum).unwrap();
                    let dec = codec.decode_slice(&rec);
                    let (chi2, p) = null.score_test(dec[0], &dec[1..=d], dec[d + 1]);
                    assert_eq!(
                        chi2.to_bits(),
                        ref_chi2.to_bits(),
                        "d={d} snp={s} threads={threads} isa={isa:?}: {chi2} vs {ref_chi2}"
                    );
                    assert_eq!(
                        p.to_bits(),
                        ref_p.to_bits(),
                        "d={d} snp={s} threads={threads} isa={isa:?}"
                    );
                }
            }
        }
    }
}

/// Gate 2: the fused per-SNP kernel under the auto-resolved ISA is
/// bit-identical to the scalar reference twin — U, every bₖ, and q —
/// at lane-straddling dimensions. (Where `resolve(Auto)` is Scalar
/// this compares the reference with itself; on AVX2 hosts it is the
/// vector proof.)
#[test]
fn fused_screen_kernel_bit_identical_to_scalar_reference() {
    let auto = resolve(KernelIsa::Auto);
    for d in [1usize, 3, 4, 5, 7, 8, 16, 17] {
        let (panel, null) = fixture(d, 0x5C0_0F00 + d as u64);
        for s in 0..panel.num_snps() {
            for j in 0..panel.num_institutions() {
                let sh = &panel.shard_data()[j];
                // The residual/weight cache must itself be ISA-stable
                // (dot is bit-identical per the simd gates).
                let scr_scalar = ScreenShard::build(&sh.x, &sh.y, &null.beta, Isa::Scalar);
                let scr_auto = ScreenShard::build(&sh.x, &sh.y, &null.beta, auto);
                for (a, b) in scr_scalar.r.iter().zip(&scr_auto.r) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                let g = panel.snp_shard(s, j);
                let (ref_u, ref_b, ref_q) = snp_screen_stats_reference(&sh.x, &scr_scalar, g);
                let mut b = vec![0.0; d];
                let (u, q) = snp_screen_stats(&sh.x, &scr_auto, g, auto, &mut b);
                assert_eq!(u.to_bits(), ref_u.to_bits(), "d={d} snp={s} inst={j}");
                assert_eq!(q.to_bits(), ref_q.to_bits(), "d={d} snp={s} inst={j}");
                for (k, (a, r)) in b.iter().zip(&ref_b).enumerate() {
                    assert_eq!(a.to_bits(), r.to_bits(), "d={d} snp={s} inst={j} b[{k}]");
                }
            }
        }
    }
}

/// Gate 3: after warm-up, one per-SNP institution share iteration —
/// fused score stats into the pooled summary, fused encode+share into
/// the pooled holders — allocates NOTHING, while each iteration walks
/// a different SNP column sliced from the shared panel.
#[test]
fn warm_screen_share_iteration_is_allocation_free() {
    let d = 8usize;
    let (panel, null) = fixture(d, 0x5C0_1000);
    let params = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let sh = &panel.shard_data()[0];
    let scr = ScreenShard::build(&sh.x, &sh.y, &null.beta, Isa::Scalar);
    let mut summary = vec![0.0; d + 2];
    let mut pool = SharePool::new();

    let mut iteration = |s: usize, summary: &mut Vec<f64>, pool: &mut SharePool| {
        let g = panel.snp_shard(s, 0);
        let (u, q) = {
            let (_, rest) = summary.split_at_mut(1);
            snp_screen_stats(&sh.x, &scr, g, Isa::Scalar, &mut rest[..d])
        };
        summary[0] = u;
        summary[d + 1] = q;
        encode_share_into(&ctx, &codec, summary, s as u64, 1, pool).unwrap();
        summary[0]
    };

    // Warm-up: grows the pooled holder buffers once.
    for s in 0..2 {
        iteration(s, &mut summary, &mut pool);
    }
    let before = allocs_here();
    for s in 0..panel.num_snps() {
        iteration(s, &mut summary, &mut pool);
    }
    let allocated = allocs_here() - before;
    assert_eq!(
        allocated, 0,
        "warm per-SNP screen share iterations must not allocate"
    );
    // Sanity: the measured iterations computed a real statistic.
    let g = panel.snp_shard(panel.num_snps() - 1, 0);
    let (ref_u, _, _) = snp_screen_stats_reference(&sh.x, &scr, g);
    assert_eq!(summary[0].to_bits(), ref_u.to_bits());
}
