//! Integration: the AOT JAX/Pallas artifact, loaded through PJRT,
//! must agree elementwise with the pure-rust twin — this is the
//! cross-layer correctness contract of the whole architecture.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not
//! been built; `make artifacts && cargo test` exercises them fully.

use privlr::linalg::Matrix;
use privlr::model;
use privlr::runtime::{ComputeHandle, Manifest};
use privlr::util::rng::{Rng, SplitMix64};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    Manifest::load(&artifacts_dir()).is_ok()
}

fn random_shard(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let mut x = Matrix::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            x[(i, j)] = rng.next_gaussian();
        }
        y[i] = f64::from(rng.next_bernoulli(0.35));
    }
    let beta: Vec<f64> = (0..d).map(|_| rng.next_range_f64(-0.5, 0.5)).collect();
    (x, y, beta)
}

#[test]
fn pjrt_matches_rust_twin_exactly() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let (handle, _guard) = ComputeHandle::pjrt(&artifacts_dir()).unwrap();
    // Exercise a shard SMALLER than the bucket (tests the masking) at
    // the test bucket (128, 8).
    for (n, seed) in [(100usize, 1u64), (128, 2), (7, 3)] {
        let (x, y, beta) = random_shard(n, 8, seed);
        let got = handle.local_stats(&x, &y, &beta).unwrap();
        let expect = model::local_stats(&x, &y, &beta);
        assert!(
            got.h.max_abs_diff(&expect.h) < 1e-9,
            "H mismatch at n={n}: {}",
            got.h.max_abs_diff(&expect.h)
        );
        for (a, b) in got.g.iter().zip(&expect.g) {
            assert!((a - b).abs() < 1e-9, "g mismatch at n={n}: {a} vs {b}");
        }
        assert!(
            (got.dev - expect.dev).abs() < 1e-8,
            "dev mismatch at n={n}: {} vs {}",
            got.dev,
            expect.dev
        );
    }
}

#[test]
fn pjrt_bucket_reuse_is_cached_and_consistent() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let (handle, _guard) = ComputeHandle::pjrt(&artifacts_dir()).unwrap();
    let (x, y, beta) = random_shard(64, 8, 11);
    let first = handle.local_stats(&x, &y, &beta).unwrap();
    // Second call hits the compiled-executable cache; results identical.
    let second = handle.local_stats(&x, &y, &beta).unwrap();
    assert_eq!(first.h.data, second.h.data);
    assert_eq!(first.g, second.g);
    assert_eq!(first.dev, second.dev);
}

#[test]
fn pjrt_missing_bucket_is_a_clean_error() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let (handle, _guard) = ComputeHandle::pjrt(&artifacts_dir()).unwrap();
    // d=13 has no artifact.
    let (x, y, beta) = random_shard(16, 13, 21);
    let err = handle.local_stats(&x, &y, &beta).unwrap_err().to_string();
    assert!(err.contains("no artifact bucket"), "{err}");
}

#[test]
fn secure_fit_runs_on_pjrt_engine() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    // End-to-end: the secure protocol with the PJRT engine matches the
    // centralized gold standard, proving all three layers compose.
    let ds = privlr::data::synthetic("t", 600, 6, 3, 0.0, 1.0, 31);
    let cfg = privlr::config::ExperimentConfig {
        engine: privlr::config::EngineKind::Pjrt,
        artifacts_dir: artifacts_dir().to_string_lossy().into_owned(),
        max_iters: 30,
        ..Default::default()
    };
    let secure = privlr::coordinator::secure_fit(&ds, &cfg).unwrap();
    let gold = privlr::baseline::centralized_fit(&ds, cfg.lambda, cfg.tol, 30).unwrap();
    for (a, b) in secure.beta.iter().zip(&gold.beta) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}
