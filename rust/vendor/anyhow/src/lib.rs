//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build set has no crates.io access, so this shim provides
//! the (small) subset of the real crate's API that this repository
//! uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics match the real crate for that subset:
//!
//! * `Error` boxes any `std::error::Error + Send + Sync + 'static` and
//!   deliberately does NOT implement `std::error::Error` itself, so the
//!   blanket `From<E>` conversion and the reflexive `From<Error>` used
//!   by `?` coexist — the same coherence trick the real crate relies on;
//! * `{:#}` (alternate `Display`) prints the full source chain
//!   colon-separated, `{:?}` prints the message plus a `Caused by:`
//!   chain, matching how the rest of the crate formats fatal errors.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with display/chain formatting.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// Build an error from a displayable message (what `anyhow!` does).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// The lowest-level source in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = &*self.0;
        while let Some(next) = cur.source() {
            cur = next;
        }
        cur
    }

    /// Iterate the source chain, starting with the outermost error.
    pub fn chain(&self) -> Chain<'_> {
        Chain {
            next: Some(&*self.0),
        }
    }
}

/// Iterator over an error's source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);
    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)?;
        if f.alternate() {
            let mut src = self.0.source();
            while let Some(s) = src {
                write!(f, ": {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut src = self.0.source();
        if src.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = src {
            write!(f, "\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl std::ops::Deref for Error {
    type Target = dyn StdError + Send + Sync + 'static;
    fn deref(&self) -> &Self::Target {
        &*self.0
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Message-only error carrier behind [`Error::msg`].
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::core::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "inner boom")
    }

    #[test]
    fn question_mark_converts_and_propagates() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        fn outer() -> Result<()> {
            inner()?; // reflexive Error -> Error
            Ok(())
        }
        let e = outer().unwrap_err();
        assert!(e.to_string().contains("inner boom"));
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
    }

    #[test]
    fn alternate_display_prints_chain() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer")
            }
        }
        impl StdError for Outer {
            fn source(&self) -> Option<&(dyn StdError + 'static)> {
                Some(&self.0)
            }
        }
        let e = Error::new(Outer(io_err()));
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner boom");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause().to_string(), "inner boom");
    }
}
