//! Regenerates **Fig 4** — running time (central and total) as the
//! number of participating institutions grows 10 → 100 with 10,000
//! records each (so N grows 100k → 1M too).
//!
//!     cargo bench --bench fig4_scaling
//!
//! Paper's shape: total time ~flat (3.0–3.3 s on their box) because
//! institutions compute in parallel; central time ~flat and tiny
//! (~0.088 s) because secure aggregation is O(S·d²) on small summaries.

use privlr::bench::{default_report_path, print_kv_table, update_json_report};
use privlr::config::{EngineKind, ExperimentConfig};
use privlr::coordinator::secure_fit;
use privlr::data::synthetic;
use privlr::util::json::{self, Json};
use privlr::util::stats::mean;

fn main() {
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let institution_counts: Vec<usize> = if fast {
        vec![10, 20, 40]
    } else {
        vec![10, 20, 30, 40, 50, 60, 70, 80, 90, 100]
    };
    let records_per_institution = 10_000;
    let reps = if fast { 1 } else { 2 };

    let cfg_base = ExperimentConfig {
        engine: EngineKind::Auto,
        max_iters: 50,
        ..Default::default()
    };

    let mut rows = Vec::new();
    let mut totals = Vec::new();
    let mut centrals = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    for &s in &institution_counts {
        let n = s * records_per_institution;
        let ds = synthetic("scale", n, 6, s, 0.0, 1.0, 42);
        let mut t_total = Vec::new();
        let mut t_central = Vec::new();
        let mut t_emulated = Vec::new();
        let mut iters = 0;
        for _ in 0..reps {
            let fit = secure_fit(&ds, &cfg_base).expect("secure fit");
            t_total.push(fit.metrics.total_secs);
            t_central.push(fit.metrics.central_secs);
            // Emulated distributed total: in deployment every institution
            // runs on ITS OWN hardware, so the local phase costs one
            // institution's compute (mean over institutions, since the
            // simulation time-slices them on shared cores) + protection +
            // the central phase. This is the quantity whose flatness the
            // paper's Fig 4 demonstrates.
            t_emulated.push(
                fit.metrics.local_compute_sum_secs / s as f64
                    + fit.metrics.protect_secs
                    + fit.metrics.central_secs,
            );
            iters = fit.metrics.iterations;
        }
        eprintln!("fig4: S={s:>3} (N={n:>7}) total={:.3}s central={:.3}s", mean(&t_total), mean(&t_central));
        rows.push(vec![
            s.to_string(),
            n.to_string(),
            iters.to_string(),
            format!("{:.4}", mean(&t_central)),
            format!("{:.3}", mean(&t_total)),
            format!("{:.4}", mean(&t_emulated)),
        ]);
        totals.push(mean(&t_emulated));
        centrals.push(mean(&t_central));
        json_rows.push(json::obj(vec![
            ("institutions", json::num(s as f64)),
            ("total_n", json::num(n as f64)),
            ("iterations", json::num(iters as f64)),
            ("central_s", json::num(mean(&t_central))),
            ("sim_wall_s", json::num(mean(&t_total))),
            ("emulated_distributed_s", json::num(mean(&t_emulated))),
        ]));
    }

    // Machine-readable trajectory next to the kernel numbers, so the
    // perf history is trackable PR over PR.
    let report = default_report_path();
    let section = json::obj(vec![
        ("records_per_institution", json::num(records_per_institution as f64)),
        ("fast_mode", Json::Bool(fast)),
        ("rows", json::arr(json_rows)),
    ]);
    match update_json_report(&report, "fig4_scaling", section) {
        Ok(()) => eprintln!("wrote fig4 section to {}", report.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.display()),
    }

    print_kv_table(
        "FIG 4 — scaling with the number of institutions (10k records each)",
        &[
            "institutions",
            "total N",
            "iterations",
            "central (s)",
            "sim wall (s)",
            "emulated distributed (s)",
        ],
        &rows,
    );

    // Shape assertions: the paper's claim is *minimal fluctuation*.
    // Per-institution shard size is constant, so local compute should be
    // ~flat; total N grows 10×, so allow modest growth but nothing like
    // linear-in-S blowup of the central phase per institution count.
    let c_first = centrals.first().copied().unwrap();
    let c_last = centrals.last().copied().unwrap();
    let s_ratio = *institution_counts.last().unwrap() as f64 / institution_counts[0] as f64;
    println!(
        "\ncentral time growth {}×  over a {}× institution increase",
        (c_last / c_first).max(0.0),
        s_ratio
    );
    println!(
        "emulated distributed total: first {:.4}s, last {:.4}s (paper: 3.0–3.3s flat)",
        totals.first().unwrap(),
        totals.last().unwrap()
    );
    println!("(sim wall grows with S because one machine hosts all S institutions;");
    println!(" the per-institution view — what Fig 4 measures — stays flat)");
    println!("paper reference: central ≈0.088s flat; total 3.0–3.3s flat.");
}
