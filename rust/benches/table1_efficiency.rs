//! Regenerates **Table 1** — computational efficiency on the four
//! evaluation datasets: samples, features, iterations, central
//! runtime, total runtime, data transmitted.
//!
//!     cargo bench --bench table1_efficiency
//!
//! Set `PRIVLR_BENCH_FAST=1` to shrink the 1M synthetic workload to
//! 100k rows for smoke runs. Expected *shape* vs the paper: identical
//! iteration counts (6–8), central runtime a small fraction of total,
//! seconds-scale totals; absolute values differ (different hardware
//! and languages — see EXPERIMENTS.md).

use privlr::bench::{print_kv_table, BenchConfig};
use privlr::config::{EngineKind, ExperimentConfig};
use privlr::coordinator::secure_fit;
use privlr::data::{insurance_like, paper_synthetic, parkinsons_like, synthetic, Dataset, ParkinsonsTarget};
use privlr::util::stats::mean;

fn bench_dataset(ds: &Dataset, cfg: &ExperimentConfig, iters: usize) -> Vec<String> {
    let mut totals = Vec::new();
    let mut centrals = Vec::new();
    let mut mb = 0.0;
    let mut newton_iters = 0;
    let mut wan_secs = 0.0;
    for _ in 0..iters {
        let fit = secure_fit(ds, cfg).expect("secure fit");
        totals.push(fit.metrics.total_secs);
        centrals.push(fit.metrics.central_secs);
        mb = fit.metrics.traffic.total_bytes as f64 / 1e6;
        newton_iters = fit.metrics.iterations;
        wan_secs = privlr::transport::WanModel::internet()
            .estimate_network_secs(&fit.metrics.traffic, fit.metrics.iterations);
    }
    vec![
        ds.name.clone(),
        ds.n().to_string(),
        ds.paper_features().to_string(),
        newton_iters.to_string(),
        format!("{:.3}", mean(&centrals)),
        format!("{:.3}", mean(&totals)),
        format!("{:.2}", mb),
        format!("{:.2}%", 100.0 * mean(&centrals) / mean(&totals)),
        format!("{:.2}", wan_secs),
    ]
}

fn main() {
    let bcfg = BenchConfig::from_env();
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let cfg = ExperimentConfig {
        engine: EngineKind::Auto,
        max_iters: 50,
        ..Default::default()
    };
    let reps = bcfg.measure_iters.max(2);

    let mut rows = Vec::new();
    eprintln!("table1: Insurance …");
    rows.push(bench_dataset(&insurance_like(42), &cfg, reps));
    eprintln!("table1: Parkinsons.Motor …");
    rows.push(bench_dataset(
        &parkinsons_like(ParkinsonsTarget::Motor, 42),
        &cfg,
        reps,
    ));
    eprintln!("table1: Parkinsons.Total …");
    rows.push(bench_dataset(
        &parkinsons_like(ParkinsonsTarget::Total, 42),
        &cfg,
        reps,
    ));
    if fast {
        eprintln!("table1: Synthetic 100k (PRIVLR_BENCH_FAST) …");
        rows.push(bench_dataset(
            &synthetic("Synthetic", 100_000, 6, 6, 0.0, 1.0, 42),
            &cfg,
            reps,
        ));
    } else {
        eprintln!("table1: Synthetic 1M …");
        rows.push(bench_dataset(&paper_synthetic(42), &cfg, 2));
    }

    print_kv_table(
        "TABLE 1 — computational efficiency (secure protocol)",
        &[
            "Dataset",
            "# samples",
            "# features",
            "# iterations",
            "Central (s)",
            "Total (s)",
            "Tx (MB)",
            "central/total",
            "est. WAN net (s)",
        ],
        &rows,
    );
    println!("\npaper reference: Insurance 8 iters (0.42s central / 3.77s total, 80 MB);");
    println!("Parkinsons 6 iters (~0.25s / ~2.2s, 492 MB); Synthetic-1M 6 iters (0.076s / 12.76s, 612 MB).");
    println!("shape checks: iterations within 6–8, central ≪ total. Absolute times differ by design.");
}
