//! Regenerates **Fig 3** — model convergence (per-iteration deviance)
//! for all datasets; paper: every model converges within 6–8
//! iterations under the 1e-10 deviance-change criterion.
//!
//!     cargo bench --bench fig3_convergence

use privlr::config::{EngineKind, ExperimentConfig};
use privlr::coordinator::secure_fit;
use privlr::data::{insurance_like, parkinsons_like, synthetic, ParkinsonsTarget};

fn main() {
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let cfg = ExperimentConfig {
        engine: EngineKind::Auto,
        max_iters: 50,
        ..Default::default()
    };
    let synth_n = if fast { 100_000 } else { 1_000_000 };
    let datasets = [
        insurance_like(42),
        parkinsons_like(ParkinsonsTarget::Motor, 42),
        parkinsons_like(ParkinsonsTarget::Total, 42),
        synthetic("Synthetic", synth_n, 6, 6, 0.0, 1.0, 42),
    ];

    println!("\n=== FIG 3 — model convergence (penalized deviance per iteration) ===");
    let mut traces = Vec::new();
    for ds in &datasets {
        eprintln!("fig3: {} …", ds.name);
        let fit = secure_fit(ds, &cfg).expect("secure fit");
        traces.push((ds.name.clone(), fit.metrics.deviance_trace));
    }

    // Print the series the figure plots: |Δ deviance| per iteration
    // (log scale in the paper; we print the raw numbers).
    let max_len = traces.iter().map(|(_, t)| t.len()).max().unwrap();
    print!("{:<6}", "iter");
    for (name, _) in &traces {
        print!(" {name:>22}");
    }
    println!();
    for i in 0..max_len {
        print!("{:<6}", i + 1);
        for (_, t) in &traces {
            match t.get(i) {
                Some(v) => print!(" {v:>22.6}"),
                None => print!(" {:>22}", "—"),
            }
        }
        println!();
    }
    println!("\n|Δdeviance| per iteration (convergence when < 1e-10):");
    for i in 1..max_len {
        print!("{:<6}", i + 1);
        for (_, t) in &traces {
            match (t.get(i - 1), t.get(i)) {
                (Some(a), Some(b)) => print!(" {:>22.3e}", (a - b).abs()),
                _ => print!(" {:>22}", "—"),
            }
        }
        println!();
    }

    for (name, t) in &traces {
        let iters = t.len();
        assert!(
            (4..=12).contains(&iters),
            "{name}: {iters} iterations (paper: 6–8)"
        );
        for w in t.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{name}: non-monotone deviance");
        }
    }
    println!("\npaper reference: all models converge in 6–8 iterations; Parkinsons");
    println!("Motor/Total overlap (same covariates). Shape check PASS.");
}
