//! Regenerates **Fig 2** — model accuracy of the securely-estimated β
//! against the centralized gold standard on all four datasets
//! (paper: identical, R² = 1.00).
//!
//!     cargo bench --bench fig2_accuracy

use privlr::baseline::centralized_fit;
use privlr::bench::print_kv_table;
use privlr::config::{EngineKind, ExperimentConfig};
use privlr::coordinator::secure_fit;
use privlr::data::{insurance_like, parkinsons_like, synthetic, Dataset, ParkinsonsTarget};
use privlr::util::stats::{max_abs_diff, r_squared};

fn check(ds: &Dataset, cfg: &ExperimentConfig) -> Vec<String> {
    let fit = secure_fit(ds, cfg).expect("secure fit");
    let gold = centralized_fit(ds, cfg.lambda, cfg.tol, cfg.max_iters).expect("gold");
    let r2 = r_squared(&fit.beta, &gold.beta);
    let md = max_abs_diff(&fit.beta, &gold.beta);
    vec![
        ds.name.clone(),
        format!("{:.10}", r2),
        format!("{md:.3e}"),
        fit.metrics.iterations.to_string(),
        gold.iterations.to_string(),
        if r2 > 0.999_999 { "✓".into() } else { "✗".into() },
    ]
}

fn main() {
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let cfg = ExperimentConfig {
        engine: EngineKind::Auto,
        max_iters: 50,
        ..Default::default()
    };
    let synth_n = if fast { 100_000 } else { 1_000_000 };
    let mut rows = Vec::new();
    for ds in [
        insurance_like(42),
        parkinsons_like(ParkinsonsTarget::Motor, 42),
        parkinsons_like(ParkinsonsTarget::Total, 42),
        synthetic("Synthetic", synth_n, 6, 6, 0.0, 1.0, 42),
    ] {
        eprintln!("fig2: {} …", ds.name);
        rows.push(check(&ds, &cfg));
    }
    print_kv_table(
        "FIG 2 — secure β vs centralized gold standard",
        &["Dataset", "R²", "max|Δβ|", "secure iters", "gold iters", "R²=1.00"],
        &rows,
    );
    println!("\npaper reference: R² = 1.00 on all four datasets (exact method, no approximation).");
    let all_pass = rows.iter().all(|r| r[5] == "✓");
    assert!(all_pass, "Fig 2 accuracy regression");
    println!("all datasets PASS");
}
