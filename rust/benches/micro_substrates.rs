//! Micro-benchmarks of every substrate on the protocol's hot path,
//! plus the pragmatic-vs-full ablation and the naive-secure cost-model
//! comparison. Feeds EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench micro_substrates

use privlr::bench::{black_box, print_kv_table, print_table, run_bench, run_micro, BenchConfig};
use privlr::config::{ExperimentConfig, SecurityMode};
use privlr::coordinator::secure_fit;
use privlr::field::{add_assign_slice, Fp};
use privlr::fixed::FixedCodec;
use privlr::linalg::Matrix;
use privlr::model::local_stats;
use privlr::shamir::{lagrange_at_zero, reconstruct_batch, share_batch, ShamirParams};
use privlr::util::rng::{ChaCha20Rng, Rng, SplitMix64};

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rows = Vec::new();

    // ---- field arithmetic ----
    let mut rng = SplitMix64::new(1);
    let a: Vec<Fp> = (0..4096).map(|_| Fp::random(&mut rng)).collect();
    let b: Vec<Fp> = (0..4096).map(|_| Fp::random(&mut rng)).collect();
    rows.push(run_micro("field: 4096-elt secure add (slice)", cfg, 256, || {
        let mut acc = a.clone();
        add_assign_slice(&mut acc, &b);
        acc[0]
    }));
    let x = Fp::new(123_456_789_012_345);
    rows.push(run_micro("field: mul", cfg, 65536, || {
        black_box(x).mul(black_box(x))
    }));
    rows.push(run_micro("field: inv (Fermat pow)", cfg, 4096, || {
        black_box(x).inv()
    }));

    // ---- shamir ----
    let params = ShamirParams::new(3, 5).unwrap();
    let codec = FixedCodec::default();
    let mut crng = ChaCha20Rng::seed_from_u64(2);
    let secrets: Vec<Fp> = (0..3655).map(|_| Fp::random(&mut crng)).collect(); // d=85 packed H
    rows.push(run_bench("shamir: share 3655 elts (d=85 packed H), 3-of-5", cfg, || {
        share_batch(params, &secrets, &mut crng)
    }));
    let batch = share_batch(params, &secrets, &mut crng);
    let quorum: Vec<(usize, &[Fp])> = (0..3).map(|j| (j, batch.per_holder[j].as_slice())).collect();
    rows.push(run_bench("shamir: reconstruct 3655 elts from 3 shares", cfg, || {
        reconstruct_batch(params, &quorum).unwrap()
    }));
    rows.push(run_micro("shamir: lagrange coefficients (t=3)", cfg, 4096, || {
        lagrange_at_zero(params, &[0, 2, 4]).unwrap()
    }));

    // ---- fixed point ----
    let vals: Vec<f64> = (0..3655).map(|i| (i as f64) * 0.37 - 512.0).collect();
    rows.push(run_micro("fixed: encode 3655 f64", cfg, 64, || {
        codec.encode_slice(&vals).unwrap()
    }));
    let enc = codec.encode_slice(&vals).unwrap();
    rows.push(run_micro("fixed: decode 3655 Fp", cfg, 64, || {
        codec.decode_slice(&enc)
    }));

    // ---- local stats kernel (rust twin), paper shard shapes ----
    for (n, d, label) in [
        (1965usize, 85usize, "local_stats rust: Insurance shard 1965×85"),
        (1175, 21, "local_stats rust: Parkinsons shard 1175×21"),
        (166_667, 6, "local_stats rust: Synthetic-1M shard 166667×6"),
    ] {
        let mut drng = SplitMix64::new(n as u64);
        let mut x = Matrix::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = drng.next_gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| f64::from(drng.next_bernoulli(0.3))).collect();
        let beta = vec![0.1; d];
        rows.push(run_bench(label, cfg, || local_stats(&x, &y, &beta)));
    }

    print_table("micro: substrate hot paths", &rows);

    // ---- ablation: pragmatic vs full security ----
    let ds = privlr::data::synthetic("abl", 20_000, 21, 5, 0.0, 1.0, 7);
    let mut ab_rows = Vec::new();
    for mode in [SecurityMode::Pragmatic, SecurityMode::Full] {
        let ecfg = ExperimentConfig {
            mode,
            max_iters: 50,
            ..Default::default()
        };
        let fit = secure_fit(&ds, &ecfg).unwrap();
        ab_rows.push(vec![
            mode.name().to_string(),
            format!("{:.3}", fit.metrics.total_secs),
            format!("{:.4}", fit.metrics.central_secs),
            format!("{:.4}", fit.metrics.protect_secs),
            format!("{:.2}", fit.metrics.traffic.total_bytes as f64 / 1e6),
            fit.metrics.iterations.to_string(),
        ]);
    }
    print_kv_table(
        "ablation: pragmatic vs full security (20k×20, 5 institutions)",
        &["mode", "total (s)", "central (s)", "protect (s)", "Tx (MB)", "iters"],
        &ab_rows,
    );

    // ---- cost model: hybrid vs naive centralized-secure ----
    let mut cm_rows = Vec::new();
    for (n, d, s) in [(1_000_000usize, 6usize, 6usize), (9_822, 85, 5), (5_875, 21, 5)] {
        let naive = privlr::baseline::naive_secure_op_count(n, d);
        let hybrid = privlr::baseline::hybrid_secure_op_count(s, d, true);
        cm_rows.push(vec![
            format!("{n}×{d}"),
            naive.to_string(),
            hybrid.to_string(),
            format!("{:.1e}×", naive as f64 / hybrid as f64),
        ]);
    }
    print_kv_table(
        "cost model: secure ops/iteration, naive centralized-secure vs hybrid",
        &["workload", "naive MPC ops", "hybrid secure ops", "reduction"],
        &cm_rows,
    );
    println!("\n(The orders-of-magnitude op reduction is the paper's core efficiency argument.)");
}
