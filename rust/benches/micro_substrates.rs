//! Micro-benchmarks of every substrate on the protocol's hot path,
//! plus the pragmatic-vs-full ablation and the naive-secure cost-model
//! comparison. Feeds EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench micro_substrates

use privlr::bench::{
    black_box, default_report_path, print_kv_table, print_table, run_bench, run_micro,
    summary_json, update_json_report, BenchConfig, Summary,
};
use privlr::config::{ExperimentConfig, KernelIsa, SecurityMode};
use privlr::coordinator::secure_fit;
use privlr::field::{add_assign_slice, Fp};
use privlr::fixed::FixedCodec;
use privlr::linalg::Matrix;
use privlr::model::{local_stats, local_stats_into, local_stats_reference, LocalStats, Workspace};
use privlr::secure::{encode_share_into, encode_share_into_isa, ShareContext, SharePool};
use privlr::shamir::{
    lagrange_at_zero, reconstruct_batch, reconstruct_batch_with, reconstruct_batch_with_isa,
    share_batch, share_batch_horner, share_batch_with, ShamirParams, VandermondeTable,
};
use privlr::simd::{self, Isa};
use privlr::util::json::{self, Json};
use privlr::util::rng::{ChaCha20Rng, Rng, SplitMix64};

/// Old-vs-new kernel comparison (the perf-PR acceptance numbers):
/// scalar reference vs blocked local-stats at 1/2/4 threads on the
/// n=100k, d=64 case, and Horner vs Vandermonde Shamir sharing at a
/// d²-sized batch. Returns the JSON section for BENCH_kernels.json.
fn bench_kernels(cfg: BenchConfig) -> Json {
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let (n, d) = if fast { (20_000usize, 32usize) } else { (100_000, 64) };
    let mut rng = SplitMix64::new(0xBE5);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            x[(i, j)] = rng.next_gaussian();
        }
    }
    let y: Vec<f64> = (0..n).map(|_| f64::from(rng.next_bernoulli(0.35))).collect();
    let beta: Vec<f64> = (0..d).map(|_| rng.next_range_f64(-0.5, 0.5)).collect();

    let mut rows: Vec<Summary> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let reference = run_bench(
        &format!("local_stats reference (scalar) {n}x{d}"),
        cfg,
        || local_stats_reference(&x, &y, &beta),
    );
    rows.push(reference.clone());
    entries.push(summary_json(&reference));
    let mut thread_results: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut ws = Workspace::new(d, threads);
        let mut out = LocalStats::zeros(d);
        let s = run_bench(
            &format!("local_stats blocked {n}x{d}, {threads} thread(s)"),
            cfg,
            || {
                local_stats_into(&mut ws, &x, &y, &beta, &mut out);
                out.dev
            },
        );
        thread_results.push((threads, s.mean_s));
        rows.push(s.clone());
        let mut e = summary_json(&s);
        if let Json::Obj(m) = &mut e {
            m.insert("threads".into(), json::num(threads as f64));
            m.insert(
                "speedup_vs_reference".into(),
                json::num(reference.mean_s / s.mean_s),
            );
        }
        entries.push(e);
    }

    // Shamir: d²-sized batch (the full-mode packed-Hessian share).
    let params = ShamirParams::new(3, 5).unwrap();
    let batch_len = d * d;
    let mut crng = ChaCha20Rng::seed_from_u64(11);
    let secrets: Vec<Fp> = (0..batch_len).map(|_| Fp::random(&mut crng)).collect();
    let horner = run_bench(
        &format!("share_batch horner {batch_len} elts, 3-of-5"),
        cfg,
        || share_batch_horner(params, &secrets, &mut crng),
    );
    rows.push(horner.clone());
    entries.push(summary_json(&horner));
    let table = VandermondeTable::new(params);
    let vander = run_bench(
        &format!("share_batch vandermonde {batch_len} elts, 3-of-5"),
        cfg,
        || share_batch_with(&table, &secrets, &mut crng),
    );
    rows.push(vander.clone());
    let mut ve = summary_json(&vander);
    if let Json::Obj(m) = &mut ve {
        m.insert(
            "speedup_vs_horner".into(),
            json::num(horner.mean_s / vander.mean_s),
        );
    }
    entries.push(ve);

    print_table("kernels: old vs new (perf-PR acceptance numbers)", &rows);
    let single = thread_results[0].1;
    println!(
        "\nlocal_stats {n}x{d}: blocked/1t {:.2}x vs scalar; thread scaling {}",
        reference.mean_s / single,
        thread_results
            .iter()
            .map(|(t, m)| format!("{t}t={:.2}x", single / m))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "share_batch {batch_len}: vandermonde {:.2}x vs horner",
        horner.mean_s / vander.mean_s
    );

    json::obj(vec![
        ("workload", json::s(&format!("local_stats {n}x{d} + share_batch {batch_len} (3-of-5)"))),
        ("fast_mode", Json::Bool(fast)),
        ("results", json::arr(entries)),
    ])
}

/// Old-vs-new secure-sharing pipeline (the zero-allocation threaded
/// perf-PR acceptance numbers): the per-iteration alloc path
/// (`encode_slice` + `share_batch_with`, fresh `Vec`s) vs the fused
/// pooled `encode_share_into` sweep at 1/2/4 threads, and per-call
/// Lagrange reconstruction vs cached-λ pooled `reconstruct_batch_with`
/// — all at the paper's d=85 full-mode summary size
/// ([g | dev | packed H] = 3741 elements, 3-of-5). Returns the
/// `secure_pipeline` section for BENCH_kernels.json.
fn bench_secure_pipeline(cfg: BenchConfig) -> Json {
    let d = 85usize;
    let k = d + 1 + d * (d + 1) / 2; // 3741
    let params = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let mut rng = SplitMix64::new(0x5EC);
    let values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-100.0, 100.0)).collect();

    let mut rows: Vec<Summary> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();

    // OLD share path: encode to a fresh Vec, share to fresh per-holder
    // Vecs, every call (what every full-mode iteration used to pay).
    let mut crng = ChaCha20Rng::seed_from_u64(3);
    let old_share = run_bench(
        &format!("encode+share old alloc path, {k} elts 3-of-5"),
        cfg,
        || {
            let enc = codec.encode_slice(&values).unwrap();
            ctx.share(&enc, &mut crng)
        },
    );
    rows.push(old_share.clone());
    entries.push(summary_json(&old_share));

    // NEW fused pooled sweep at 1/2/4 threads.
    let mut seed = 0u64;
    for threads in [1usize, 2, 4] {
        let mut pool = SharePool::new();
        encode_share_into(&ctx, &codec, &values, 0, threads, &mut pool).unwrap(); // warm pool
        let s = run_bench(
            &format!("encode+share fused pooled, {k} elts, {threads} thread(s)"),
            cfg,
            || {
                seed += 1;
                encode_share_into(&ctx, &codec, &values, seed, threads, &mut pool).unwrap();
                pool.holder(0)[0]
            },
        );
        rows.push(s.clone());
        let mut e = summary_json(&s);
        if let Json::Obj(m) = &mut e {
            m.insert("threads".into(), json::num(threads as f64));
            m.insert(
                "speedup_vs_old_path".into(),
                json::num(old_share.mean_s / s.mean_s),
            );
        }
        entries.push(e);
    }

    // Reconstruction: per-call Lagrange + fresh output vs cached λ +
    // pooled output (the coordinator's per-iteration reality).
    let mut pool = SharePool::new();
    encode_share_into(&ctx, &codec, &values, 42, 1, &mut pool).unwrap();
    let quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
        .iter()
        .map(|&c| (c, pool.holder(c)))
        .collect();
    let old_rec = run_bench(
        &format!("reconstruct old (λ per call, fresh out), {k} elts"),
        cfg,
        || reconstruct_batch(params, &quorum).unwrap(),
    );
    rows.push(old_rec.clone());
    entries.push(summary_json(&old_rec));
    let lambdas = lagrange_at_zero(params, &[0, 2, 4]).unwrap();
    let mut out = vec![Fp::ZERO; k];
    let new_rec = run_bench(
        &format!("reconstruct new (cached λ, pooled out), {k} elts"),
        cfg,
        || {
            reconstruct_batch_with(&lambdas, &quorum, &mut out).unwrap();
            out[0]
        },
    );
    rows.push(new_rec.clone());
    let mut e = summary_json(&new_rec);
    if let Json::Obj(m) = &mut e {
        m.insert(
            "speedup_vs_old_path".into(),
            json::num(old_rec.mean_s / new_rec.mean_s),
        );
    }
    entries.push(e);

    print_table(
        "secure pipeline: old vs new (share + reconstruct, d=85 full mode)",
        &rows,
    );

    json::obj(vec![
        (
            "workload",
            json::s(&format!(
                "fused encode+share + cached-λ reconstruct, {k} elts (d=85 [g|dev|H]), 3-of-5"
            )),
        ),
        ("results", json::arr(entries)),
    ])
}

/// ISA ablation for the f64 kernels (the SIMD-PR acceptance numbers):
/// scalar `local_stats` vs the `resolve(Auto)` ISA at 1/2/4 threads on
/// the same workload as `bench_kernels`. When the build lacks the
/// `simd` feature or the CPU lacks AVX2 the resolved ISA is `scalar`
/// and every speedup is ~1.0 — the section records which case ran.
/// Returns the `kernels_simd` section for BENCH_kernels.json.
fn bench_kernels_simd(cfg: BenchConfig) -> Json {
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let (n, d) = if fast { (20_000usize, 32usize) } else { (100_000, 64) };
    let resolved = simd::resolve(KernelIsa::Auto);
    let mut rng = SplitMix64::new(0xBE5);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        x[(i, 0)] = 1.0;
        for j in 1..d {
            x[(i, j)] = rng.next_gaussian();
        }
    }
    let y: Vec<f64> = (0..n).map(|_| f64::from(rng.next_bernoulli(0.35))).collect();
    let beta: Vec<f64> = (0..d).map(|_| rng.next_range_f64(-0.5, 0.5)).collect();

    let mut rows: Vec<Summary> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut scalar_ws = Workspace::with_isa(d, 1, Isa::Scalar);
    let mut out = LocalStats::zeros(d);
    let scalar = run_bench(
        &format!("local_stats scalar isa {n}x{d}, 1 thread"),
        cfg,
        || {
            local_stats_into(&mut scalar_ws, &x, &y, &beta, &mut out);
            out.dev
        },
    );
    rows.push(scalar.clone());
    let mut se = summary_json(&scalar);
    if let Json::Obj(m) = &mut se {
        m.insert("isa".into(), json::s(Isa::Scalar.name()));
        m.insert("threads".into(), json::num(1.0));
    }
    entries.push(se);
    for threads in [1usize, 2, 4] {
        let mut ws = Workspace::with_isa(d, threads, resolved);
        let s = run_bench(
            &format!(
                "local_stats {} isa {n}x{d}, {threads} thread(s)",
                resolved.name()
            ),
            cfg,
            || {
                local_stats_into(&mut ws, &x, &y, &beta, &mut out);
                out.dev
            },
        );
        rows.push(s.clone());
        let mut e = summary_json(&s);
        if let Json::Obj(m) = &mut e {
            m.insert("isa".into(), json::s(resolved.name()));
            m.insert("threads".into(), json::num(threads as f64));
            m.insert("speedup_vs_scalar".into(), json::num(scalar.mean_s / s.mean_s));
        }
        entries.push(e);
    }

    print_table("kernels: ISA ablation (scalar vs resolved SIMD)", &rows);
    println!(
        "\nresolved ISA: {} (feature simd: {}, avx2 detected: {})",
        resolved.name(),
        cfg!(feature = "simd"),
        simd::simd_available()
    );

    json::obj(vec![
        ("workload", json::s(&format!("local_stats {n}x{d}, ISA ablation"))),
        ("fast_mode", Json::Bool(fast)),
        ("resolved_isa", json::s(resolved.name())),
        ("feature_simd", Json::Bool(cfg!(feature = "simd"))),
        ("avx2_detected", Json::Bool(simd::simd_available())),
        ("results", json::arr(entries)),
    ])
}

/// ISA ablation for the 4-lane Mersenne share arithmetic: scalar
/// fused encode+share and cached-λ reconstruction vs the
/// `resolve(Auto)` ISA, share sweep at 1/2/4 threads, at the d=85
/// full-mode summary size. Returns the `secure_pipeline_simd` section
/// for BENCH_kernels.json.
fn bench_secure_pipeline_simd(cfg: BenchConfig) -> Json {
    let d = 85usize;
    let k = d + 1 + d * (d + 1) / 2; // 3741
    let resolved = simd::resolve(KernelIsa::Auto);
    let params = ShamirParams::new(3, 5).unwrap();
    let ctx = ShareContext::new(params);
    let codec = FixedCodec::default();
    let mut rng = SplitMix64::new(0x5EC);
    let values: Vec<f64> = (0..k).map(|_| rng.next_range_f64(-100.0, 100.0)).collect();

    let mut rows: Vec<Summary> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();

    // Scalar baseline: fused pooled sweep, 1 thread (the bit-identity
    // reference the SIMD lanes are gated against).
    let mut seed = 0u64;
    let mut scalar_pool = SharePool::new();
    encode_share_into(&ctx, &codec, &values, 0, 1, &mut scalar_pool).unwrap(); // warm
    let scalar_share = run_bench(
        &format!("encode+share scalar isa, {k} elts, 1 thread"),
        cfg,
        || {
            seed += 1;
            encode_share_into(&ctx, &codec, &values, seed, 1, &mut scalar_pool).unwrap();
            scalar_pool.holder(0)[0]
        },
    );
    rows.push(scalar_share.clone());
    let mut se = summary_json(&scalar_share);
    if let Json::Obj(m) = &mut se {
        m.insert("isa".into(), json::s(Isa::Scalar.name()));
        m.insert("threads".into(), json::num(1.0));
    }
    entries.push(se);

    for threads in [1usize, 2, 4] {
        let mut pool = SharePool::new();
        encode_share_into_isa(&ctx, &codec, &values, 0, threads, resolved, &mut pool).unwrap();
        let s = run_bench(
            &format!(
                "encode+share {} isa, {k} elts, {threads} thread(s)",
                resolved.name()
            ),
            cfg,
            || {
                seed += 1;
                encode_share_into_isa(&ctx, &codec, &values, seed, threads, resolved, &mut pool)
                    .unwrap();
                pool.holder(0)[0]
            },
        );
        rows.push(s.clone());
        let mut e = summary_json(&s);
        if let Json::Obj(m) = &mut e {
            m.insert("isa".into(), json::s(resolved.name()));
            m.insert("threads".into(), json::num(threads as f64));
            m.insert(
                "speedup_vs_scalar".into(),
                json::num(scalar_share.mean_s / s.mean_s),
            );
        }
        entries.push(e);
    }

    // Reconstruction: cached λ, pooled out, scalar vs resolved ISA.
    let mut pool = SharePool::new();
    encode_share_into(&ctx, &codec, &values, 42, 1, &mut pool).unwrap();
    let quorum: Vec<(usize, &[Fp])> = [0usize, 2, 4]
        .iter()
        .map(|&c| (c, pool.holder(c)))
        .collect();
    let lambdas = lagrange_at_zero(params, &[0, 2, 4]).unwrap();
    let mut out = vec![Fp::ZERO; k];
    let scalar_rec = run_bench(
        &format!("reconstruct scalar isa (cached λ), {k} elts"),
        cfg,
        || {
            reconstruct_batch_with(&lambdas, &quorum, &mut out).unwrap();
            out[0]
        },
    );
    rows.push(scalar_rec.clone());
    let mut re = summary_json(&scalar_rec);
    if let Json::Obj(m) = &mut re {
        m.insert("isa".into(), json::s(Isa::Scalar.name()));
    }
    entries.push(re);
    let isa_rec = run_bench(
        &format!("reconstruct {} isa (cached λ), {k} elts", resolved.name()),
        cfg,
        || {
            reconstruct_batch_with_isa(&lambdas, &quorum, &mut out, resolved).unwrap();
            out[0]
        },
    );
    rows.push(isa_rec.clone());
    let mut e = summary_json(&isa_rec);
    if let Json::Obj(m) = &mut e {
        m.insert("isa".into(), json::s(resolved.name()));
        m.insert(
            "speedup_vs_scalar".into(),
            json::num(scalar_rec.mean_s / isa_rec.mean_s),
        );
    }
    entries.push(e);

    print_table(
        "secure pipeline: ISA ablation (4-lane Mersenne share arithmetic)",
        &rows,
    );

    json::obj(vec![
        (
            "workload",
            json::s(&format!(
                "ISA ablation: fused encode+share + cached-λ reconstruct, {k} elts (d=85), 3-of-5"
            )),
        ),
        ("resolved_isa", json::s(resolved.name())),
        ("feature_simd", Json::Bool(cfg!(feature = "simd"))),
        ("avx2_detected", Json::Bool(simd::simd_available())),
        ("results", json::arr(entries)),
    ])
}

fn main() {
    let cfg = BenchConfig::from_env();

    let kernels = bench_kernels(cfg);
    let report = default_report_path();
    match update_json_report(&report, "kernels", kernels) {
        Ok(()) => println!("\nwrote kernel section to {}", report.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", report.display()),
    }

    let secure_pipeline = bench_secure_pipeline(cfg);
    match update_json_report(&report, "secure_pipeline", secure_pipeline) {
        Ok(()) => println!("wrote secure_pipeline section to {}", report.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.display()),
    }

    let kernels_simd = bench_kernels_simd(cfg);
    match update_json_report(&report, "kernels_simd", kernels_simd) {
        Ok(()) => println!("wrote kernels_simd section to {}", report.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.display()),
    }

    let secure_pipeline_simd = bench_secure_pipeline_simd(cfg);
    match update_json_report(&report, "secure_pipeline_simd", secure_pipeline_simd) {
        Ok(()) => println!("wrote secure_pipeline_simd section to {}", report.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.display()),
    }

    let mut rows = Vec::new();

    // ---- field arithmetic ----
    let mut rng = SplitMix64::new(1);
    let a: Vec<Fp> = (0..4096).map(|_| Fp::random(&mut rng)).collect();
    let b: Vec<Fp> = (0..4096).map(|_| Fp::random(&mut rng)).collect();
    rows.push(run_micro("field: 4096-elt secure add (slice)", cfg, 256, || {
        let mut acc = a.clone();
        add_assign_slice(&mut acc, &b);
        acc[0]
    }));
    let x = Fp::new(123_456_789_012_345);
    rows.push(run_micro("field: mul", cfg, 65536, || {
        black_box(x).mul(black_box(x))
    }));
    rows.push(run_micro("field: inv (Fermat pow)", cfg, 4096, || {
        black_box(x).inv()
    }));

    // ---- shamir ----
    let params = ShamirParams::new(3, 5).unwrap();
    let codec = FixedCodec::default();
    let mut crng = ChaCha20Rng::seed_from_u64(2);
    let secrets: Vec<Fp> = (0..3655).map(|_| Fp::random(&mut crng)).collect(); // d=85 packed H
    rows.push(run_bench("shamir: share 3655 elts (d=85 packed H), 3-of-5", cfg, || {
        share_batch(params, &secrets, &mut crng)
    }));
    let batch = share_batch(params, &secrets, &mut crng);
    let quorum: Vec<(usize, &[Fp])> = (0..3).map(|j| (j, batch.per_holder[j].as_slice())).collect();
    rows.push(run_bench("shamir: reconstruct 3655 elts from 3 shares", cfg, || {
        reconstruct_batch(params, &quorum).unwrap()
    }));
    rows.push(run_micro("shamir: lagrange coefficients (t=3)", cfg, 4096, || {
        lagrange_at_zero(params, &[0, 2, 4]).unwrap()
    }));

    // ---- fixed point ----
    let vals: Vec<f64> = (0..3655).map(|i| (i as f64) * 0.37 - 512.0).collect();
    rows.push(run_micro("fixed: encode 3655 f64", cfg, 64, || {
        codec.encode_slice(&vals).unwrap()
    }));
    let enc = codec.encode_slice(&vals).unwrap();
    rows.push(run_micro("fixed: decode 3655 Fp", cfg, 64, || {
        codec.decode_slice(&enc)
    }));

    // ---- local stats kernel (rust twin), paper shard shapes ----
    for (n, d, label) in [
        (1965usize, 85usize, "local_stats rust: Insurance shard 1965×85"),
        (1175, 21, "local_stats rust: Parkinsons shard 1175×21"),
        (166_667, 6, "local_stats rust: Synthetic-1M shard 166667×6"),
    ] {
        let mut drng = SplitMix64::new(n as u64);
        let mut x = Matrix::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = drng.next_gaussian();
        }
        let y: Vec<f64> = (0..n).map(|_| f64::from(drng.next_bernoulli(0.3))).collect();
        let beta = vec![0.1; d];
        rows.push(run_bench(label, cfg, || local_stats(&x, &y, &beta)));
    }

    print_table("micro: substrate hot paths", &rows);

    // ---- ablation: pragmatic vs full security ----
    let ds = privlr::data::synthetic("abl", 20_000, 21, 5, 0.0, 1.0, 7);
    let mut ab_rows = Vec::new();
    for mode in [SecurityMode::Pragmatic, SecurityMode::Full] {
        let ecfg = ExperimentConfig {
            mode,
            max_iters: 50,
            ..Default::default()
        };
        let fit = secure_fit(&ds, &ecfg).unwrap();
        ab_rows.push(vec![
            mode.name().to_string(),
            format!("{:.3}", fit.metrics.total_secs),
            format!("{:.4}", fit.metrics.central_secs),
            format!("{:.4}", fit.metrics.protect_secs),
            format!("{:.2}", fit.metrics.traffic.total_bytes as f64 / 1e6),
            fit.metrics.iterations.to_string(),
        ]);
    }
    print_kv_table(
        "ablation: pragmatic vs full security (20k×20, 5 institutions)",
        &["mode", "total (s)", "central (s)", "protect (s)", "Tx (MB)", "iters"],
        &ab_rows,
    );

    // ---- cost model: hybrid vs naive centralized-secure ----
    let mut cm_rows = Vec::new();
    for (n, d, s) in [(1_000_000usize, 6usize, 6usize), (9_822, 85, 5), (5_875, 21, 5)] {
        let naive = privlr::baseline::naive_secure_op_count(n, d);
        let hybrid = privlr::baseline::hybrid_secure_op_count(s, d, true);
        cm_rows.push(vec![
            format!("{n}×{d}"),
            naive.to_string(),
            hybrid.to_string(),
            format!("{:.1e}×", naive as f64 / hybrid as f64),
        ]);
    }
    print_kv_table(
        "cost model: secure ops/iteration, naive centralized-secure vs hybrid",
        &["workload", "naive MPC ops", "hybrid secure ops", "reduction"],
        &cm_rows,
    );
    println!("\n(The orders-of-magnitude op reduction is the paper's core efficiency argument.)");
}
