//! Aggregate throughput of the session-multiplexed study engine:
//! fits/sec at S=4 institutions for K ∈ {1, 4, 16} concurrent
//! sessions, at the paper's small (d=10) and wide (d=85) dimensions.
//!
//!     cargo bench --bench session_throughput
//!
//! One persistent engine per (d, K) cell; each sample submits K
//! identical studies and joins them all, so the measured time is the
//! makespan of K interleaved fits on one network. The `speedup_vs_k1`
//! column is the throughput ratio against the K=1 cell of the same d —
//! how much the multiplexing amortizes network setup and fills compute
//! gaps (centers idle while institutions crunch, and vice versa).

use privlr::bench::{
    default_report_path, print_kv_table, run_bench, summary_json, update_json_report, BenchConfig,
    Summary,
};
use privlr::config::ExperimentConfig;
use privlr::data::synthetic;
use privlr::engine::{StudyEngine, SubmitOptions};
use privlr::util::json::{self, Json};

fn main() {
    let bcfg = BenchConfig::from_env();
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let s = 4usize;
    let n = if fast { 2_000 } else { 20_000 };
    let ks = [1usize, 4, 16];

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    for d in [10usize, 85] {
        let ds = synthetic("bench", n, d, s, 0.0, 1.0, 42);
        let cfg = ExperimentConfig {
            max_iters: 30,
            ..ExperimentConfig::default()
        };
        let mut k1_fits_per_sec = f64::NAN;
        // Split once per dataset: sessions share the Arc'd shards, so
        // the measured makespan is protocol work, not dataset copying.
        let shards = privlr::session::ShardData::split(&ds);
        for k in ks {
            let engine = StudyEngine::for_experiment(&ds, &cfg).expect("engine");
            let name = format!("multifit n={n} d={d} S={s} K={k}");
            let summary: Summary = run_bench(&name, bcfg, || {
                let handles: Vec<_> = (0..k)
                    .map(|_| {
                        engine
                            .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                            .expect("submit")
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("join").metrics.iterations)
                    .sum::<u32>()
            });
            engine.shutdown().expect("shutdown");
            let fits_per_sec = k as f64 / summary.mean_s;
            if k == 1 {
                k1_fits_per_sec = fits_per_sec;
            }
            let speedup = fits_per_sec / k1_fits_per_sec;
            rows.push(vec![
                format!("d={d}"),
                format!("K={k}"),
                format!("{:.3}s", summary.mean_s),
                format!("{fits_per_sec:.2}"),
                format!("{speedup:.2}x"),
            ]);
            let mut entry = summary_json(&summary);
            if let Json::Obj(map) = &mut entry {
                map.insert("concurrent_sessions".into(), json::num(k as f64));
                map.insert("d".into(), json::num(d as f64));
                map.insert("institutions".into(), json::num(s as f64));
                map.insert("fits_per_sec".into(), json::num(fits_per_sec));
                map.insert("speedup_vs_k1".into(), json::num(speedup));
            }
            entries.push(entry);
        }
    }

    print_kv_table(
        "session engine throughput (S=4)",
        &["dim", "sessions", "makespan", "fits/sec", "vs K=1"],
        &rows,
    );

    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K concurrent sessions on one persistent network (makespan of K joined submissions, mean over samples)"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    let path = default_report_path();
    if let Err(e) = update_json_report(&path, "session_throughput", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\nreport section 'session_throughput' written to {}", path.display());
    }
}
