//! Aggregate throughput of the session-multiplexed study engine:
//! fits/sec at S=4 institutions for K ∈ {1, 4, 16} concurrent
//! sessions, at the paper's small (d=10) and wide (d=85) dimensions —
//! plus a `shard_scaling` sweep of the sharded control plane
//! (driver_shards ∈ {1, 2, 4} at K=16), a `fault_recovery` sweep under
//! worker churn, a `wan_consortium` sweep under injected WAN
//! round-trips (0/20/80 ms RTT at K=16, d=10), and a `dp_release`
//! sweep of the differentially private release layer (DP off vs
//! Gaussian ε=1: one extra joint-noise round per fit).
//!
//!     cargo bench --bench session_throughput
//!
//! One persistent engine per cell; each sample submits K identical
//! studies and joins them all, so the measured time is the makespan of
//! K interleaved fits on one network. The `speedup_vs_k1` column is
//! the throughput ratio against the K=1 cell of the same d — how much
//! the multiplexing amortizes network setup and fills compute gaps
//! (centers idle while institutions crunch, and vice versa). The
//! shard sweep's `speedup_vs_1shard` isolates what parallelizing the
//! coordinator itself buys once K is high enough for driver dispatch
//! to contend.

use privlr::bench::{
    default_report_path, print_kv_table, run_bench, summary_json, update_json_report, BenchConfig,
    Summary,
};
use privlr::config::ExperimentConfig;
use privlr::data::{synthetic, synthetic_panel};
use privlr::engine::{EngineOptions, StudyEngine, SubmitOptions, SubmitPolicy};
use privlr::model::NullModelCache;
use privlr::util::json::{self, Json};
use std::sync::Arc;

fn main() {
    let bcfg = BenchConfig::from_env();
    let fast = std::env::var("PRIVLR_BENCH_FAST").as_deref() == Ok("1");
    let s = 4usize;
    let n = if fast { 2_000 } else { 20_000 };
    let ks = [1usize, 4, 16];

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    for d in [10usize, 85] {
        let ds = synthetic("bench", n, d, s, 0.0, 1.0, 42);
        let cfg = ExperimentConfig {
            max_iters: 30,
            ..ExperimentConfig::default()
        };
        let mut k1_fits_per_sec = f64::NAN;
        // Split once per dataset: sessions share the Arc'd shards, so
        // the measured makespan is protocol work, not dataset copying.
        let shards = privlr::session::ShardData::split(&ds);
        for k in ks {
            let engine = StudyEngine::for_experiment(&ds, &cfg).expect("engine");
            let name = format!("multifit n={n} d={d} S={s} K={k}");
            let summary: Summary = run_bench(&name, bcfg, || {
                let handles: Vec<_> = (0..k)
                    .map(|_| {
                        engine
                            .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                            .expect("submit")
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("join").metrics.iterations)
                    .sum::<u32>()
            });
            engine.shutdown().expect("shutdown");
            let fits_per_sec = k as f64 / summary.mean_s;
            if k == 1 {
                k1_fits_per_sec = fits_per_sec;
            }
            let speedup = fits_per_sec / k1_fits_per_sec;
            rows.push(vec![
                format!("d={d}"),
                format!("K={k}"),
                format!("{:.3}s", summary.mean_s),
                format!("{fits_per_sec:.2}"),
                format!("{speedup:.2}x"),
            ]);
            let mut entry = summary_json(&summary);
            if let Json::Obj(map) = &mut entry {
                map.insert("concurrent_sessions".into(), json::num(k as f64));
                map.insert("d".into(), json::num(d as f64));
                map.insert("institutions".into(), json::num(s as f64));
                map.insert("fits_per_sec".into(), json::num(fits_per_sec));
                map.insert("speedup_vs_k1".into(), json::num(speedup));
            }
            entries.push(entry);
        }
    }

    print_kv_table(
        "session engine throughput (S=4)",
        &["dim", "sessions", "makespan", "fits/sec", "vs K=1"],
        &rows,
    );

    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K concurrent sessions on one persistent network (makespan of K joined submissions, mean over samples)"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    let path = default_report_path();
    if let Err(e) = update_json_report(&path, "session_throughput", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("\nreport section 'session_throughput' written to {}", path.display());
    }

    // ---- shard_scaling: the sharded control plane at K=16 ----------
    // Fixed workload (d=10, the coordination-bound shape: small local
    // phase, many rounds), driver_shards swept over {1, 2, 4}. Results
    // are bit-identical at every shard count (gated by
    // tests/integration_sessions.rs); this sweep measures only the
    // wall-clock effect of parallelizing coordination.
    let k = 16usize;
    let d = 10usize;
    let ds = synthetic("bench-shards", n, d, s, 0.0, 1.0, 42);
    let shards = privlr::session::ShardData::split(&ds);
    let cfg = ExperimentConfig {
        max_iters: 30,
        ..ExperimentConfig::default()
    };
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut one_shard_fits_per_sec = f64::NAN;
    for driver_shards in [1usize, 2, 4] {
        let engine = StudyEngine::with_options(
            s,
            cfg.num_centers,
            EngineOptions { driver_shards, ..Default::default() },
        )
        .expect("engine");
        let name = format!("multifit n={n} d={d} S={s} K={k} shards={driver_shards}");
        let summary: Summary = run_bench(&name, bcfg, || {
            let handles: Vec<_> = (0..k)
                .map(|_| {
                    engine
                        .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                        .expect("submit")
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("join").metrics.iterations)
                .sum::<u32>()
        });
        engine.shutdown().expect("shutdown");
        let fits_per_sec = k as f64 / summary.mean_s;
        if driver_shards == 1 {
            one_shard_fits_per_sec = fits_per_sec;
        }
        let speedup = fits_per_sec / one_shard_fits_per_sec;
        rows.push(vec![
            format!("shards={driver_shards}"),
            format!("K={k}"),
            format!("{:.3}s", summary.mean_s),
            format!("{fits_per_sec:.2}"),
            format!("{speedup:.2}x"),
        ]);
        let mut entry = summary_json(&summary);
        if let Json::Obj(map) = &mut entry {
            map.insert("driver_shards".into(), json::num(driver_shards as f64));
            map.insert("concurrent_sessions".into(), json::num(k as f64));
            map.insert("d".into(), json::num(d as f64));
            map.insert("institutions".into(), json::num(s as f64));
            map.insert("fits_per_sec".into(), json::num(fits_per_sec));
            map.insert("speedup_vs_1shard".into(), json::num(speedup));
        }
        entries.push(entry);
    }
    print_kv_table(
        "sharded driver scaling (S=4, d=10, K=16)",
        &["shards", "sessions", "makespan", "fits/sec", "vs 1 shard"],
        &rows,
    );
    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K=16 concurrent sessions with coordination sharded across driver_shards ∈ {1,2,4} (same workload, bit-identical results; measures coordinator parallelism only)"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    if let Err(e) = update_json_report(&path, "shard_scaling", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("report section 'shard_scaling' written to {}", path.display());
    }

    // ---- fault_recovery: throughput under worker churn at K=16 -----
    // Same fixed workload (d=10, K=16), but a fraction of the sample's
    // sessions experience a worker death: the bench thread kills a
    // rotating institution mid-makespan and restarts it immediately,
    // so affected sessions take the suspend → re-admit → replay path
    // (RetryPolicy: 3 retries, 10ms backoff). The death rate maps to
    // kill events per sample: 0% → 0, 5% → 1, 20% → 3 at K=16. The
    // overhead column is the fits/sec ratio against the 0% cell — the
    // price of recovery, not of faults (replay is bit-identical;
    // sessions whose budget is exhausted anyway are counted aborted).
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut no_death_fits_per_sec = f64::NAN;
    for death_rate in [0.0f64, 0.05, 0.20] {
        let kills = (k as f64 * death_rate).round() as usize;
        let engine = StudyEngine::with_options(
            s,
            cfg.num_centers,
            EngineOptions {
                retry: privlr::engine::RetryPolicy {
                    max_retries: 3,
                    backoff: std::time::Duration::from_millis(10),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .expect("engine");
        let name = format!("multifit n={n} d={d} S={s} K={k} deaths={kills}");
        let mut completed = 0u64;
        let mut aborted = 0u64;
        let summary: Summary = run_bench(&name, bcfg, || {
            let handles: Vec<_> = (0..k)
                .map(|_| {
                    engine
                        .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                        .expect("submit")
                })
                .collect();
            for i in 0..kills {
                std::thread::sleep(std::time::Duration::from_millis(5));
                let j = i % s;
                engine.kill_institution(j).expect("kill");
                engine.restart_institution(j).expect("restart");
            }
            let mut iters = 0u32;
            for h in handles {
                match h.join() {
                    Ok(fit) => {
                        completed += 1;
                        iters += fit.metrics.iterations;
                    }
                    // A session can exhaust its budget when several
                    // kills land on it; that is the policy working.
                    Err(_) => aborted += 1,
                }
            }
            iters
        });
        engine.shutdown().expect("shutdown");
        let fits_per_sec = k as f64 / summary.mean_s;
        if death_rate == 0.0 {
            no_death_fits_per_sec = fits_per_sec;
        }
        let overhead = fits_per_sec / no_death_fits_per_sec;
        rows.push(vec![
            format!("{:.0}%", death_rate * 100.0),
            format!("kills={kills}"),
            format!("{:.3}s", summary.mean_s),
            format!("{fits_per_sec:.2}"),
            format!("{overhead:.2}x"),
        ]);
        let mut entry = summary_json(&summary);
        if let Json::Obj(map) = &mut entry {
            map.insert("death_rate".into(), json::num(death_rate));
            map.insert("kills_per_sample".into(), json::num(kills as f64));
            map.insert("concurrent_sessions".into(), json::num(k as f64));
            map.insert("d".into(), json::num(d as f64));
            map.insert("institutions".into(), json::num(s as f64));
            map.insert("fits_per_sec".into(), json::num(fits_per_sec));
            map.insert("vs_no_deaths".into(), json::num(overhead));
            map.insert("completed".into(), json::num(completed as f64));
            map.insert("aborted".into(), json::num(aborted as f64));
        }
        entries.push(entry);
    }
    print_kv_table(
        "fault recovery throughput (S=4, d=10, K=16; kill+restart mid-makespan)",
        &["deaths", "events", "makespan", "fits/sec", "vs 0%"],
        &rows,
    );
    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K=16 concurrent sessions while a rotating institution worker is killed and restarted mid-makespan at 0%/5%/20% death rates (RetryPolicy: 3 retries, 10ms backoff; recovered fits replay bit-identically)"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    if let Err(e) = update_json_report(&path, "fault_recovery", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("report section 'fault_recovery' written to {}", path.display());
    }

    // ---- wan_consortium: fits/sec with an ocean between members ----
    // Same fixed workload (d=10, K=16) with the deterministic WAN
    // shaper installed on the in-memory engine: every link gets rtt/2
    // of one-way latency (zero jitter, unbounded bandwidth), so each
    // protocol request/response pair pays one full RTT — the
    // transport-independent cost model for a geo-distributed consortium
    // (the TCP fabric of `--features net` adds real sockets, not
    // different round-trip counts). 0 ms is the unshaped baseline; the
    // `vs_lan` column is how much of the LAN throughput survives 20 ms
    // (continental) and 80 ms (transoceanic) round trips, with K=16
    // concurrent sessions overlapping their wait states.
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut lan_fits_per_sec = f64::NAN;
    for rtt_ms in [0u64, 20, 80] {
        let engine = StudyEngine::with_options(s, cfg.num_centers, EngineOptions::default())
            .expect("engine");
        if rtt_ms > 0 {
            engine.install_wan(privlr::transport::WanPlan::symmetric_rtt(
                std::time::Duration::from_millis(rtt_ms),
                std::time::Duration::ZERO,
                0,
                42,
            ));
        }
        let name = format!("multifit n={n} d={d} S={s} K={k} rtt={rtt_ms}ms");
        let summary: Summary = run_bench(&name, bcfg, || {
            let handles: Vec<_> = (0..k)
                .map(|_| {
                    engine
                        .submit_shared(&cfg, shards.clone(), SubmitOptions::default())
                        .expect("submit")
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("join").metrics.iterations)
                .sum::<u32>()
        });
        engine.clear_wan();
        engine.shutdown().expect("shutdown");
        let fits_per_sec = k as f64 / summary.mean_s;
        if rtt_ms == 0 {
            lan_fits_per_sec = fits_per_sec;
        }
        let vs_lan = fits_per_sec / lan_fits_per_sec;
        rows.push(vec![
            format!("rtt={rtt_ms}ms"),
            format!("K={k}"),
            format!("{:.3}s", summary.mean_s),
            format!("{fits_per_sec:.2}"),
            format!("{vs_lan:.2}x"),
        ]);
        let mut entry = summary_json(&summary);
        if let Json::Obj(map) = &mut entry {
            map.insert("rtt_ms".into(), json::num(rtt_ms as f64));
            map.insert("concurrent_sessions".into(), json::num(k as f64));
            map.insert("d".into(), json::num(d as f64));
            map.insert("institutions".into(), json::num(s as f64));
            map.insert("fits_per_sec".into(), json::num(fits_per_sec));
            map.insert("vs_lan".into(), json::num(vs_lan));
        }
        entries.push(entry);
    }
    print_kv_table(
        "WAN consortium throughput (S=4, d=10, K=16; symmetric RTT, zero jitter)",
        &["rtt", "sessions", "makespan", "fits/sec", "vs LAN"],
        &rows,
    );
    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K=16 concurrent sessions under the deterministic WAN shaper (symmetric_rtt: every link rtt/2 one-way, zero jitter, unbounded bandwidth) at 0/20/80 ms RTT — results bit-identical to unshaped (shaping reorders time, not bytes)"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    if let Err(e) = update_json_report(&path, "wan_consortium", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("report section 'wan_consortium' written to {}", path.display());
    }

    // ---- dp_release: the cost of releasing privately ---------------
    // Same fixed workload (d=10, K=16), DP off vs DP on (Gaussian
    // ε=1, unbounded budget). The DP column pays exactly ONE extra
    // protocol round per fit — the joint noise round — plus the
    // accountant charge at submission; against a ~30-round Newton fit
    // the expected overhead is a few percent, and that is what the
    // vs_dp_off column verifies. DP-off numerics are bit-identical to
    // the pre-DP engine (gated by the existing suites, not timed here).
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    let mut dp_off_fits_per_sec = f64::NAN;
    for dp_on in [false, true] {
        let mut dp_cfg = cfg.clone();
        if dp_on {
            dp_cfg.dp = Some(privlr::dp::DpConfig::default());
        }
        let engine = StudyEngine::with_options(s, cfg.num_centers, EngineOptions::default())
            .expect("engine");
        let name = format!("multifit n={n} d={d} S={s} K={k} dp={}", if dp_on { "on" } else { "off" });
        let summary: Summary = run_bench(&name, bcfg, || {
            let handles: Vec<_> = (0..k)
                .map(|_| {
                    engine
                        .submit_shared(&dp_cfg, shards.clone(), SubmitOptions::default())
                        .expect("submit")
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    let fit = h.join().expect("join");
                    assert_eq!(fit.dp.is_some(), dp_on, "release mode mismatch");
                    fit.metrics.iterations
                })
                .sum::<u32>()
        });
        let charges = engine.dp_accountant().charges();
        engine.shutdown().expect("shutdown");
        let fits_per_sec = k as f64 / summary.mean_s;
        if !dp_on {
            dp_off_fits_per_sec = fits_per_sec;
        }
        let vs_off = fits_per_sec / dp_off_fits_per_sec;
        rows.push(vec![
            format!("dp={}", if dp_on { "on" } else { "off" }),
            format!("K={k}"),
            format!("{:.3}s", summary.mean_s),
            format!("{fits_per_sec:.2}"),
            format!("{vs_off:.2}x"),
        ]);
        let mut entry = summary_json(&summary);
        if let Json::Obj(map) = &mut entry {
            map.insert("dp".into(), if dp_on { json::s("gaussian eps=1") } else { json::s("off") });
            map.insert("concurrent_sessions".into(), json::num(k as f64));
            map.insert("d".into(), json::num(d as f64));
            map.insert("institutions".into(), json::num(s as f64));
            map.insert("fits_per_sec".into(), json::num(fits_per_sec));
            map.insert("vs_dp_off".into(), json::num(vs_off));
            map.insert("accountant_charges".into(), json::num(charges as f64));
        }
        entries.push(entry);
    }
    print_kv_table(
        "DP release overhead (S=4, d=10, K=16; one joint noise round per fit)",
        &["mode", "sessions", "makespan", "fits/sec", "vs DP off"],
        &rows,
    );
    let report = json::obj(vec![
        (
            "note",
            json::s("fits/sec of K=16 concurrent sessions with the DP release layer off vs on (Gaussian ε=1, δ=1e-6, unbounded budget): the DP cells pay one extra joint-noise protocol round per fit plus the accountant charge; accountant_charges counts ledger entries across all samples of the cell"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    if let Err(e) = update_json_report(&path, "dp_release", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("report section 'dp_release' written to {}", path.display());
    }

    // ---- gwas_screen: SNPs/sec of the score-test screening sweep ---
    // The GWAS fast path: one cached null model, then a streamed sweep
    // of single-round `ScoreScreen` sessions (window 64, bulk lane).
    // The promotion threshold is +∞ so the cell measures PURE screen
    // throughput — no full fits mixed into the makespan; decision
    // parity is gated by tests/integration_gwas.rs, not timed here.
    // Swept over panel size {10³, 10⁴} SNPs (FAST: {200, 1000}) and
    // driver_shards ∈ {1, 4}: at 10⁴ single-round sessions the control
    // plane itself is the bottleneck, which is what sharding buys.
    let gwas_n = if fast { 1_000 } else { 4_000 };
    let gwas_d = 6usize;
    let snp_counts: [usize; 2] = if fast { [200, 1_000] } else { [1_000, 10_000] };
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut entries: Vec<Json> = Vec::new();
    for num_snps in snp_counts {
        let panel = Arc::new(synthetic_panel(
            "bench-gwas",
            gwas_n,
            gwas_d,
            s,
            num_snps,
            (num_snps / 100).max(1),
            0.5,
            42,
        ));
        let mut one_shard_snps_per_sec = f64::NAN;
        for driver_shards in [1usize, 4] {
            let engine = StudyEngine::with_options(
                s,
                cfg.num_centers,
                EngineOptions { driver_shards, ..Default::default() },
            )
            .expect("engine");
            // The null fit is per-consortium setup, outside the timer.
            let null_fit = engine
                .submit_shared(&cfg, panel.shard_data().to_vec(), SubmitOptions::interactive())
                .expect("submit null")
                .join()
                .expect("null fit");
            let null = Arc::new(
                NullModelCache::new(
                    null_fit.beta.clone(),
                    null_fit.fisher.as_ref().expect("fisher"),
                    cfg.lambda,
                )
                .expect("null cache"),
            );
            let name = format!("gwas_screen n={gwas_n} d={gwas_d} S={s} snps={num_snps} shards={driver_shards}");
            let summary: Summary = run_bench(&name, bcfg, || {
                let report = engine
                    .screen_sweep(
                        &cfg,
                        &panel,
                        &null,
                        f64::INFINITY,
                        64,
                        SubmitOptions::bulk().policy(SubmitPolicy::ShedOldestBulk),
                    )
                    .expect("sweep");
                assert_eq!(report.shed, 0, "unbounded lanes must not shed");
                report.screened as u32
            });
            engine.shutdown().expect("shutdown");
            let snps_per_sec = num_snps as f64 / summary.mean_s;
            if driver_shards == 1 {
                one_shard_snps_per_sec = snps_per_sec;
            }
            let speedup = snps_per_sec / one_shard_snps_per_sec;
            rows.push(vec![
                format!("snps={num_snps}"),
                format!("shards={driver_shards}"),
                format!("{:.3}s", summary.mean_s),
                format!("{snps_per_sec:.0}"),
                format!("{speedup:.2}x"),
            ]);
            let mut entry = summary_json(&summary);
            if let Json::Obj(map) = &mut entry {
                map.insert("num_snps".into(), json::num(num_snps as f64));
                map.insert("driver_shards".into(), json::num(driver_shards as f64));
                map.insert("n".into(), json::num(gwas_n as f64));
                map.insert("d".into(), json::num(gwas_d as f64));
                map.insert("institutions".into(), json::num(s as f64));
                map.insert("snps_per_sec".into(), json::num(snps_per_sec));
                map.insert("speedup_vs_1shard".into(), json::num(speedup));
            }
            entries.push(entry);
        }
    }
    print_kv_table(
        "GWAS screen throughput (S=4, d=6; streamed single-round score tests, window 64)",
        &["panel", "shards", "makespan", "SNPs/sec", "vs 1 shard"],
        &rows,
    );
    let report = json::obj(vec![
        (
            "note",
            json::s("SNPs/sec of the streamed secure score-test screen (cached null model, single-round O(d) sessions, bulk lane, in-flight window 64, threshold +∞ so no full fits are timed) at panel sizes {1e3, 1e4} SNPs x driver_shards {1, 4}"),
        ),
        ("results", Json::Arr(entries)),
    ]);
    if let Err(e) = update_json_report(&path, "gwas_screen", report) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("report section 'gwas_screen' written to {}", path.display());
    }
}
