"""Make `import compile` work when pytest is invoked from the repo root
(e.g. `pytest python/tests/ -q`) as well as from python/."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
