"""Layer 2: the JAX compute graph the rust coordinator executes.

`local_stats` is the function that gets AOT-lowered (one HLO artifact
per shape bucket) and called from `rust/src/runtime.rs` on every
institution, every Newton iteration. It delegates the heavy pass to
the Pallas kernel (Layer 1) and is numerically identical to
`kernels.ref.local_stats_ref` and to the rust twin in
`rust/src/model.rs`.

Everything here is build-time only: python never runs on the request
path. f64 is enabled because the protocol's R^2 = 1.00 exactness claim
(paper Fig 2) is checked at ~1e-9 against the centralized gold
standard, beyond f32 resolution on ill-conditioned workloads.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels.local_stats import local_stats_kernel  # noqa: E402
from .kernels.ref import local_stats_ref  # noqa: E402


def local_stats(x, y, mask, beta, *, block_n=None):
    """Per-institution summary statistics (H_j, g_j, dev_j).

    This is the exported artifact entrypoint (`block_n=None` →
    VMEM-budgeted auto tile, see `kernels.local_stats.auto_block_n`).
    Returns a 3-tuple; the AOT pipeline lowers it with
    return_tuple=True so the rust side unpacks with `to_tuple3`.
    """
    return local_stats_kernel(x, y, mask, beta, block_n=block_n)


def local_stats_jnp(x, y, mask, beta):
    """Pure-jnp variant (no Pallas) — used for L2-level A/B testing and
    as a lowering fallback."""
    return local_stats_ref(x, y, mask, beta)


def newton_direction(h, g, beta, lam):
    """Regularized Newton direction (Eq. 3): solve (H + lam I) delta =
    g - lam*beta.

    The production protocol performs this solve in rust on the
    reconstructed global aggregates (the d x d system is tiny); this JAX
    twin exists for end-to-end testing of the compute graph and for the
    future fully-secure variant the paper sketches (secure matrix
    inversion), where the solve itself would be lowered too.
    """
    d = beta.shape[0]
    a = h + lam * jnp.eye(d, dtype=h.dtype)
    rhs = g - lam * beta
    return jnp.linalg.solve(a, rhs)


def predict_proba(x, beta):
    """sigma(X beta) — inference-time scoring."""
    return jax.nn.sigmoid(x @ beta)


def make_example_args(n, d, dtype=jnp.float64):
    """ShapeDtypeStructs for AOT lowering of `local_stats` at (n, d)."""
    return (
        jax.ShapeDtypeStruct((n, d), dtype),  # x
        jax.ShapeDtypeStruct((n,), dtype),  # y
        jax.ShapeDtypeStruct((n,), dtype),  # mask
        jax.ShapeDtypeStruct((d,), dtype),  # beta
    )
