"""AOT pipeline: lower the L2 model to HLO text artifacts for rust.

Usage (normally via `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts

Produces one `local_stats_n{N}_d{D}.hlo.txt` per shape bucket plus
`manifest.json` describing them. The rust runtime pads each
institution's shard into the smallest bucket that fits (masked rows
contribute zero), so a handful of buckets covers all workloads:

    (2048,  85)  Insurance shards      (9822/5 ~ 1965 rows, 84+1 features)
    (2048,  21)  Parkinsons shards     (5875/5 = 1175 rows, 20+1)
    (262144, 6)  Synthetic-1M shards   (1e6/6 ~ 166667 rows, 6)
    (16384,  6)  Fig-4 scaling shards  (10000 rows/institution)
    (1024,   6)  quickstart/small runs
    (128,    8)  integration-test bucket

INTERCHANGE FORMAT: HLO *text*, not serialized HloModuleProto — the
xla_extension 0.5.1 linked by the rust `xla` crate rejects jax>=0.5
protos (64-bit instruction ids); the text parser reassigns ids and
round-trips cleanly. Lowered with return_tuple=True; rust unpacks the
1-tuple-of-3 via to_tuple3.
"""

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# (rows, features-incl-intercept) shape buckets — see module docstring.
DEFAULT_BUCKETS = [
    (2048, 85),
    (2048, 21),
    (262144, 6),
    (16384, 6),
    (1024, 6),
    (128, 8),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, d: int) -> str:
    """Lower `local_stats` for one (n, d) bucket to HLO text."""
    args = model.make_example_args(n, d)
    lowered = jax.jit(model.local_stats).lower(*args)
    return to_hlo_text(lowered)


def build(out_dir: str, buckets=None, force: bool = False) -> dict:
    """Build all artifacts; skips buckets whose file already exists
    unless `force`. Returns the manifest dict."""
    buckets = buckets or DEFAULT_BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for n, d in buckets:
        name = f"local_stats_n{n}_d{d}.hlo.txt"
        path = os.path.join(out_dir, name)
        if force or not os.path.exists(path):
            t0 = time.time()
            text = lower_bucket(n, d)
            with open(path, "w") as f:
                f.write(text)
            print(f"  lowered ({n:>7}, {d:>3}) -> {name}: "
                  f"{len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s")
        else:
            print(f"  cached  ({n:>7}, {d:>3}) -> {name}")
        entries.append({"path": name, "n": n, "d": d})
    manifest = {"artifacts": entries, "dtype": "f64",
                "format": "hlo-text/return-tuple"}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')} "
          f"({len(entries)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if artifact files exist")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated n:d pairs, e.g. 1024:6,2048:21")
    args = ap.parse_args()
    buckets = None
    if args.buckets:
        buckets = [tuple(int(v) for v in b.split(":")) for b in args.buckets.split(",")]
    build(args.out_dir, buckets=buckets, force=args.force)


if __name__ == "__main__":
    main()
