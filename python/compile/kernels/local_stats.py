"""Pallas kernel: blocked local-statistics accumulation (Layer 1).

The hot spot of the whole protocol is the per-institution pass over the
shard: `H_j = X^T diag(w) X` plus the gradient and deviance rides. This
kernel tiles the row dimension into `(BLOCK_N, d)` VMEM blocks and, per
grid step,

  1. computes `z = X_b @ beta` (a `(BLOCK_N, d) @ (d,)` matvec),
  2. derives `p`, `w = p(1-p)*mask`, the residual and the log-likelihood
     elementwise on the VPU,
  3. performs the rank-d update `X_b^T (w . X_b)` as a single
     `(d, BLOCK_N) @ (BLOCK_N, d)` matmul — the MXU-shaped op —
  4. accumulates H/g/dev into output refs that map every grid step to
     the same block (the classic reduction-output pattern).

TPU mapping notes (DESIGN.md "Hardware adaptation"): the accumulators
live in the output VMEM block across grid steps; X streams HBM->VMEM
once per iteration; per-tile VMEM = BLOCK_N*d*8 + d*d*8 + O(d) bytes,
so BLOCK_N=512 at d=85 is ~3.6 MB f64 (~1.8 MB bf16/f32 on real TPU) —
comfortably inside a 16 MB VMEM budget.

interpret=True is REQUIRED here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers
to plain HLO so the same artifact runs under the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import log_sigmoid

# Default row-tile height. 512 keeps the f64 VMEM estimate under 4 MB
# at the widest paper workload (d=85) while giving the MXU a deep
# contraction dimension; see DESIGN.md for the sweep.
DEFAULT_BLOCK_N = 512

# Per-tile VMEM budget for auto block sizing (f64 CPU artifacts). A real
# TPU has ~16 MB VMEM/core; we budget 4 MB for the X tile so the H
# accumulator, vectors and double-buffering headroom fit comfortably.
AUTO_VMEM_TILE_BYTES = 4 * 2**20


def auto_block_n(n: int, d: int, itemsize: int = 8) -> int:
    """Pick the largest power-of-two row tile that (a) divides n when
    n is a power-of-two bucket, (b) keeps the X tile within the VMEM
    budget, and (c) is at least 512 rows for MXU contraction depth.

    Perf note (EXPERIMENTS.md §Perf): interpret-mode grid steps carry a
    fixed per-step overhead, so narrow workloads (small d) want TALL
    tiles — switching the 262144×6 bucket from 512-row tiles (512
    steps) to 16384-row tiles (16 steps) cut end-to-end Synthetic-1M
    runtime ~6×. On real TPU the same rule holds until the tile
    approaches the VMEM budget.
    """
    budget_rows = max(1, AUTO_VMEM_TILE_BYTES // (d * itemsize))
    bn = 512
    while bn * 2 <= budget_rows and bn * 2 <= n:
        bn *= 2
    return min(bn, n)


def _kernel(x_ref, y_ref, m_ref, beta_ref, h_ref, g_ref, dev_ref):
    """One grid step over a (BLOCK_N, d) row tile."""
    i = pl.program_id(0)
    x = x_ref[...]  # (bn, d)
    y = y_ref[...]  # (bn,)
    m = m_ref[...]  # (bn,)
    beta = beta_ref[...]  # (d,)

    z = x @ beta  # (bn,)
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p) * m
    # MXU-shaped rank-d update: (d, bn) @ (bn, d).
    h = (x * w[:, None]).T @ x
    r = m * (y - p)
    g = r @ x
    ll = y * log_sigmoid(z) + (1.0 - y) * log_sigmoid(-z)
    dev = -2.0 * jnp.sum(m * ll)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = h
        g_ref[...] = g
        dev_ref[...] = dev.reshape(1)

    @pl.when(i > 0)
    def _accum():
        h_ref[...] += h
        g_ref[...] += g
        dev_ref[...] += dev.reshape(1)


@functools.partial(jax.jit, static_argnames=("block_n",))
def local_stats_kernel(x, y, mask, beta, *, block_n=None):
    """Blocked Pallas computation of (H_j, g_j, dev_j) for one shard.

    `block_n=None` picks the tile height via [`auto_block_n`].
    Requires `x.shape[0] % min(block_n, n) == 0`; the AOT shape buckets
    are powers of two so this always holds for artifact shapes.
    """
    n, d = x.shape
    if block_n is None:
        block_n = auto_block_n(n, d)
    bn = min(block_n, n)
    if n % bn != 0:
        raise ValueError(f"rows {n} not divisible by block {bn}")
    grid = (n // bn,)
    dtype = x.dtype
    out_shapes = (
        jax.ShapeDtypeStruct((d, d), dtype),
        jax.ShapeDtypeStruct((d,), dtype),
        jax.ShapeDtypeStruct((1,), dtype),
    )
    h, g, dev = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),  # X row tiles
            pl.BlockSpec((bn,), lambda i: (i,)),  # y row tiles
            pl.BlockSpec((bn,), lambda i: (i,)),  # mask row tiles
            pl.BlockSpec((d,), lambda i: (0,)),  # beta, replicated
        ],
        out_specs=(
            pl.BlockSpec((d, d), lambda i: (0, 0)),  # H accumulator
            pl.BlockSpec((d,), lambda i: (0,)),  # g accumulator
            pl.BlockSpec((1,), lambda i: (0,)),  # dev accumulator
        ),
        out_shape=out_shapes,
        interpret=True,  # CPU-PJRT compatibility; see module docstring
    )(x, y, mask, beta)
    return h, g, dev[0]


def vmem_bytes(block_n: int, d: int, itemsize: int = 8) -> int:
    """Estimated per-step VMEM footprint of the kernel (DESIGN.md/EXPERIMENTS.md
    use this for the TPU feasibility analysis): X tile + H/g accumulators +
    y/mask/beta vectors + the w/r temporaries."""
    x_tile = block_n * d
    h_acc = d * d
    vectors = 2 * block_n + d + d  # y, mask, beta, g
    temps = 4 * block_n  # z, p, w, r
    return (x_tile + h_acc + vectors + temps) * itemsize


def mxu_flops_per_step(block_n: int, d: int) -> int:
    """MXU flops per grid step (the rank-d update dominates)."""
    return 2 * block_n * d * d
