"""Pure-jnp oracle for the local-statistics computation (L1 ground truth).

The distributed Newton-Raphson protocol needs, per institution and per
iteration (paper Eqs. 4-6, in 0/1 response coding):

    H_j   = sum_i  m_i * w_i * x_i x_i^T        (w_i = p_i (1 - p_i))
    g_j   = sum_i  m_i * (y_i - p_i) * x_i
    dev_j = -2 sum_i m_i * (y_i log p_i + (1 - y_i) log(1 - p_i))

`mask` (m) carries the row-padding scheme used by the AOT shape
buckets: padded rows have m_i = 0 and contribute exactly zero to all
three statistics. The Pallas kernel in `local_stats.py` must match
this function elementwise (pytest enforces it); the rust twin is
`rust/src/model.rs::local_stats`.
"""

import jax
import jax.numpy as jnp


def log_sigmoid(z):
    """Numerically stable log(sigmoid(z)) = -softplus(-z)."""
    return -jax.nn.softplus(-z)


def local_stats_ref(x, y, mask, beta):
    """Reference local statistics.

    Args:
      x:    (n, d) design matrix (leading intercept column by convention).
      y:    (n,) 0/1 responses.
      mask: (n,) 1.0 for real rows, 0.0 for padding.
      beta: (d,) current coefficient estimate.

    Returns:
      (h, g, dev): (d, d) Hessian part, (d,) gradient part, () deviance.
    """
    z = x @ beta
    p = jax.nn.sigmoid(z)
    w = p * (1.0 - p) * mask
    h = (x * w[:, None]).T @ x
    r = mask * (y - p)
    g = r @ x
    # Stable deviance: y*log p + (1-y)*log(1-p) via log-sigmoid.
    ll = y * log_sigmoid(z) + (1.0 - y) * log_sigmoid(-z)
    dev = -2.0 * jnp.sum(mask * ll)
    return h, g, dev
