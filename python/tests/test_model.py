"""L2 correctness: the exported model function and Newton math."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def make_problem(n=400, d=5, seed=0, lam=1.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    x[:, 0] = 1.0
    beta_true = rng.uniform(-1, 1, size=d)
    p = 1.0 / (1.0 + np.exp(-(x @ beta_true)))
    y = (rng.random(n) < p).astype(np.float64)
    return (
        jnp.asarray(x),
        jnp.asarray(y),
        jnp.ones(n, dtype=jnp.float64),
        lam,
    )


def test_local_stats_equals_jnp_variant():
    x, y, mask, _ = make_problem()
    beta = jnp.zeros(x.shape[1], dtype=jnp.float64)
    a = model.local_stats(x, y, mask, beta, block_n=100)
    b = model.local_stats_jnp(x, y, mask, beta)
    for u, v in zip(a, b):
        np.testing.assert_allclose(u, v, atol=1e-10)


def test_newton_iteration_converges_and_is_stationary():
    x, y, mask, lam = make_problem()
    d = x.shape[1]
    beta = jnp.zeros(d, dtype=jnp.float64)
    for _ in range(25):
        h, g, _ = model.local_stats(x, y, mask, beta, block_n=100)
        delta = model.newton_direction(h, g, beta, lam)
        beta = beta + delta
    # KKT: g - lam*beta == 0 at the optimum.
    _, g, _ = model.local_stats(x, y, mask, beta, block_n=100)
    np.testing.assert_allclose(np.asarray(g), lam * np.asarray(beta), atol=1e-8)


def test_newton_matches_two_institution_decomposition():
    # Fitting on the pooled data == fitting on summed shard stats
    # (Eqs. 4-6): the algebraic core of the paper.
    x, y, mask, lam = make_problem(n=300)
    beta = jnp.asarray([0.1, -0.2, 0.3, 0.0, 0.05])
    h_all, g_all, dev_all = model.local_stats(x, y, mask, beta, block_n=150)
    h1, g1, dev1 = model.local_stats(x[:100], y[:100], mask[:100], beta, block_n=50)
    h2, g2, dev2 = model.local_stats(x[100:], y[100:], mask[100:], beta, block_n=50)
    np.testing.assert_allclose(h1 + h2, h_all, atol=1e-10)
    np.testing.assert_allclose(g1 + g2, g_all, atol=1e-10)
    np.testing.assert_allclose(dev1 + dev2, dev_all, atol=1e-10)


def test_predict_proba_bounds():
    x, _, _, _ = make_problem()
    beta = jnp.asarray([5.0, -3.0, 2.0, 0.0, 1.0])
    p = model.predict_proba(x, beta)
    assert float(p.min()) >= 0.0 and float(p.max()) <= 1.0


def test_example_args_shapes():
    args = model.make_example_args(128, 8)
    assert args[0].shape == (128, 8)
    assert args[1].shape == (128,)
    assert args[2].shape == (128,)
    assert args[3].shape == (8,)
    assert all(a.dtype == jnp.float64 for a in args)


def test_x64_is_enabled():
    # The artifact contract is f64; a silent x32 downgrade would break
    # the rust runtime's to_vec::<f64>().
    assert jax.config.jax_enable_x64
    x, y, mask, _ = make_problem(n=64)
    h, g, dev = model.local_stats(x, y, mask, jnp.zeros(5, dtype=jnp.float64), block_n=64)
    assert h.dtype == jnp.float64
    assert g.dtype == jnp.float64
    assert dev.dtype == jnp.float64
