"""AOT pipeline tests: lowering, manifest, HLO-text format contract."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import aot, model


def test_lower_bucket_produces_parseable_hlo_text():
    text = aot.lower_bucket(32, 4)
    # The rust loader's contract: HLO text with an ENTRY computation and
    # an f64 tuple result of (d,d), (d,), scalar-ish shapes.
    assert "ENTRY" in text
    assert "f64[32,4]" in text
    assert "f64[4,4]" in text
    # return_tuple=True => tuple root
    assert "tuple" in text.lower()


def test_build_writes_manifest_and_is_idempotent(tmp_path):
    out = str(tmp_path / "arts")
    manifest = aot.build(out, buckets=[(32, 4), (64, 3)])
    files = sorted(os.listdir(out))
    assert "manifest.json" in files
    assert "local_stats_n32_d4.hlo.txt" in files
    assert "local_stats_n64_d3.hlo.txt" in files
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["artifacts"] == [
        {"path": "local_stats_n32_d4.hlo.txt", "n": 32, "d": 4},
        {"path": "local_stats_n64_d3.hlo.txt", "n": 64, "d": 3},
    ]
    # Second build with identical buckets skips lowering (cache check:
    # mtimes must not change).
    mtimes = {f: os.path.getmtime(os.path.join(out, f)) for f in files}
    aot.build(out, buckets=[(32, 4), (64, 3)])
    for f in files:
        if f.endswith(".hlo.txt"):
            assert os.path.getmtime(os.path.join(out, f)) == mtimes[f]


def test_force_rebuild_rewrites(tmp_path):
    out = str(tmp_path / "arts")
    aot.build(out, buckets=[(32, 4)])
    path = os.path.join(out, "local_stats_n32_d4.hlo.txt")
    before = os.path.getmtime(path)
    os.utime(path, (before - 100, before - 100))
    aot.build(out, buckets=[(32, 4)], force=True)
    assert os.path.getmtime(path) > before - 100


def test_lowered_function_numerics_via_jit():
    # The exact function being lowered (jitted local_stats at a bucket
    # shape) must equal the reference on padded data -- this is what the
    # rust runtime executes.
    import jax.numpy as jnp

    from compile.kernels.ref import local_stats_ref

    n, d, real = 64, 4, 39
    rng = np.random.default_rng(5)
    x = np.zeros((n, d))
    x[:real] = rng.normal(size=(real, d))
    y = np.zeros(n)
    y[:real] = (rng.random(real) < 0.5).astype(float)
    mask = np.zeros(n)
    mask[:real] = 1.0
    beta = rng.normal(size=d) * 0.2

    fitted = jax.jit(model.local_stats)(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(beta)
    )
    expect = local_stats_ref(
        jnp.asarray(x[:real]),
        jnp.asarray(y[:real]),
        jnp.ones(real),
        jnp.asarray(beta),
    )
    for got, ref in zip(fitted, expect):
        np.testing.assert_allclose(got, ref, atol=1e-10)


def test_default_buckets_cover_paper_workloads():
    buckets = set(aot.DEFAULT_BUCKETS)
    # Insurance: 9822/5 institutions = 1965 rows max, d=85.
    assert any(n >= 1965 and d == 85 for n, d in buckets)
    # Parkinsons: 1175 rows, d=21.
    assert any(n >= 1175 and d == 21 for n, d in buckets)
    # Synthetic 1M over 6 institutions: 166667 rows, d=6.
    assert any(n >= 166667 and d == 6 for n, d in buckets)
    # Fig 4 scaling: 10000 rows/institution, d=6.
    assert any(n >= 10000 and d == 6 for n, d in buckets)
