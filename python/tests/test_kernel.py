"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, masks and dtypes; exact paper
shapes are pinned as regression cases. This is the build-time gate
that guards the artifact the rust runtime will execute.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.local_stats import (
    auto_block_n,
    local_stats_kernel,
    mxu_flops_per_step,
    vmem_bytes,
)
from compile.kernels.ref import local_stats_ref


def make_case(n, d, seed, mask_tail=0, x_scale=1.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)) * x_scale
    x[:, 0] = 1.0
    y = (rng.random(n) < 0.4).astype(np.float64)
    mask = np.ones(n)
    if mask_tail:
        mask[n - mask_tail:] = 0.0
    beta = rng.normal(size=d) * 0.5
    return (jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask), jnp.asarray(beta))


def assert_matches_ref(args, block_n, atol=1e-10):
    h_k, g_k, dev_k = local_stats_kernel(*args, block_n=block_n)
    h_r, g_r, dev_r = local_stats_ref(*args)
    np.testing.assert_allclose(h_k, h_r, atol=atol, rtol=1e-12)
    np.testing.assert_allclose(g_k, g_r, atol=atol, rtol=1e-12)
    np.testing.assert_allclose(dev_k, dev_r, atol=atol, rtol=1e-12)


@settings(max_examples=40, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([8, 16, 32]),
    d=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
    mask_frac=st.floats(min_value=0.0, max_value=0.9),
)
def test_kernel_matches_ref_hypothesis(n_blocks, block, d, seed, mask_frac):
    n = n_blocks * block
    mask_tail = int(n * mask_frac)
    args = make_case(n, d, seed, mask_tail=mask_tail)
    assert_matches_ref(args, block_n=block)


@pytest.mark.parametrize(
    "n,d",
    [
        (2048, 85),   # Insurance bucket
        (2048, 21),   # Parkinsons bucket
        (1024, 6),    # small synthetic bucket
        (128, 8),     # integration-test bucket
    ],
)
def test_kernel_paper_buckets(n, d):
    args = make_case(n, d, seed=7, mask_tail=n // 3)
    assert_matches_ref(args, block_n=512, atol=1e-9)


def test_kernel_single_block_degenerate():
    # n smaller than block_n: kernel must clamp the block.
    args = make_case(8, 3, seed=1)
    assert_matches_ref(args, block_n=512)


def test_kernel_rejects_ragged_grid():
    args = make_case(100, 4, seed=2)
    with pytest.raises(ValueError, match="not divisible"):
        local_stats_kernel(*args, block_n=64)


def test_fully_masked_shard_is_zero():
    x, y, _, beta = make_case(64, 5, seed=3)
    mask = jnp.zeros(64, dtype=jnp.float64)
    h, g, dev = local_stats_kernel(x, y, mask, beta, block_n=32)
    assert float(jnp.abs(h).max()) == 0.0
    assert float(jnp.abs(g).max()) == 0.0
    assert float(dev) == 0.0


def test_extreme_beta_is_stable():
    # Saturated sigmoids must not produce NaN/inf (stable log-sigmoid).
    x, y, mask, _ = make_case(64, 4, seed=4, x_scale=10.0)
    beta = jnp.asarray([50.0, -50.0, 30.0, -30.0])
    h, g, dev = local_stats_kernel(x, y, mask, beta, block_n=32)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(g).all())
    assert bool(jnp.isfinite(dev))


@given(dtype=st.sampled_from([jnp.float32, jnp.float64]))
@settings(max_examples=4, deadline=None)
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 4)), dtype=dtype)
    y = jnp.asarray((rng.random(32) < 0.5), dtype=dtype)
    mask = jnp.ones(32, dtype=dtype)
    beta = jnp.asarray(rng.normal(size=4) * 0.3, dtype=dtype)
    h, g, dev = local_stats_kernel(x, y, mask, beta, block_n=16)
    h_r, g_r, dev_r = local_stats_ref(x, y, mask, beta)
    atol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(h, h_r, atol=atol)
    np.testing.assert_allclose(g, g_r, atol=atol)
    np.testing.assert_allclose(dev, dev_r, atol=atol)
    assert h.dtype == dtype


def test_vmem_estimate_within_tpu_budget():
    # The widest paper workload must fit a 16 MB VMEM at the default tile.
    assert vmem_bytes(512, 85) < 16 * 2**20
    # And the flops estimate is the rank-d update.
    assert mxu_flops_per_step(512, 85) == 2 * 512 * 85 * 85


def test_auto_block_properties():
    # Auto tiles: power-of-two-friendly, >=512 when possible, within the
    # VMEM budget, never taller than the bucket.
    from compile.kernels.local_stats import AUTO_VMEM_TILE_BYTES

    for n, d in [(262144, 6), (16384, 6), (2048, 85), (2048, 21), (1024, 6), (128, 8)]:
        bn = auto_block_n(n, d)
        assert bn <= n
        assert n % bn == 0, f"({n},{d}): tile {bn} must divide the bucket"
        if bn < n:  # whenever the bucket is tiled, each tile fits the budget
            assert bn * d * 8 <= AUTO_VMEM_TILE_BYTES
    # narrow data gets tall tiles (the §Perf fix: fewer grid steps)
    assert auto_block_n(262144, 6) > auto_block_n(262144, 85)


def test_auto_block_matches_ref_numerically():
    # The tile height must not change the answer.
    args = make_case(1024, 6, seed=11, mask_tail=100)
    h_a, g_a, dev_a = local_stats_kernel(*args)  # auto
    h_b, g_b, dev_b = local_stats_kernel(*args, block_n=128)
    np.testing.assert_allclose(h_a, h_b, atol=1e-10)
    np.testing.assert_allclose(g_a, g_b, atol=1e-10)
    np.testing.assert_allclose(dev_a, dev_b, atol=1e-10)
