#!/usr/bin/env bash
# Tier-1 verify + kernel-equivalence gate.
#
#   ./ci.sh            build + full test suite + explicit kernel gate
#   PRIVLR_CI_BENCH=1 ./ci.sh   additionally runs the fast benches and
#                               refreshes BENCH_kernels.json
#
# The kernel-equivalence property tests (tests/prop_kernels.rs) are run
# by `cargo test` already; they are re-run by name afterwards so a
# kernel regression fails loudly and legibly even in -q output.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — install the rust toolchain" >&2
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== kernel equivalence gate (blocked SYRK / Vandermonde sharing) =="
cargo test -q --test prop_kernels

if [ "${PRIVLR_CI_BENCH:-0}" = "1" ]; then
    echo "== fast benches (refresh BENCH_kernels.json) =="
    PRIVLR_BENCH_FAST=1 cargo bench --bench micro_substrates
fi

echo "CI OK"
