#!/usr/bin/env bash
# Tier-1 verify + style gates + kernel/session-engine equivalence gates.
#
#   ./ci.sh            build + style gates + full test suite + explicit
#                      gates + feature matrix (simd, net, net+simd)
#   PRIVLR_CI_BENCH=1 ./ci.sh   additionally runs the fast benches and
#                               refreshes BENCH_kernels.json
#   PRIVLR_CHAOS=1 ./ci.sh      additionally re-runs the sharded
#                               bit-identity gate under seeded random
#                               fault plans (drop/delay/duplicate)
#
# The kernel-equivalence (tests/prop_kernels.rs) and session-engine
# (tests/integration_sessions.rs) suites are run by `cargo test`
# already; they are re-run by name afterwards so a regression in either
# fails loudly and legibly even in -q output.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — install the rust toolchain" >&2
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== kernel equivalence gate (blocked SYRK / Vandermonde sharing) =="
cargo test -q --test prop_kernels

echo "== session engine gate (concurrent == sequential, bitwise; capped + prioritized + sharded) =="
cargo test -q --test integration_sessions
cargo test -q --test prop_session_codec

echo "== control plane gate (lifecycle machine, CloseAck leak detection, auto-retire invariant, backpressure) =="
cargo test -q --test integration_lifecycle

echo "== secure pipeline gate (fused share thread-invariance + zero-alloc) =="
cargo test -q --test prop_secure_pipeline

echo "== gwas-screen gate (score-test bit-identity + zero-alloc share path + screening ≡ exhaustive decisions) =="
cargo test -q --test prop_score_screen
cargo test -q --test integration_gwas

echo "== dp-release gate (noise-share determinism + field-exact folds + accountant exhaustion + attack closure) =="
cargo test -q --test prop_dp
cargo test -q --test integration_attack

echo "== feature matrix: --features simd (vector kernels, bit-identity gates) =="
# The simd feature compiles the AVX2 kernel bodies; at runtime they are
# taken only on CPUs with AVX2 (resolve(Auto)), so these gates are the
# real vector-vs-scalar bit-identity proof on such hosts and a no-op
# re-run of the scalar reference elsewhere. Both outcomes must be green.
cargo build --release --features simd
cargo test -q --features simd
cargo test -q --features simd --test prop_kernels
cargo test -q --features simd --test prop_secure_pipeline
cargo test -q --features simd --test prop_score_screen
cargo test -q --features simd --test prop_dp

echo "== feature matrix: --features net (TCP transport, hardened framing) =="
# The net feature adds the std::net fabric + `privlr serve`; the default
# build stays socket-free. The named gate proves loopback-TCP ≡
# in-memory bit-identity, mid-fit socket-kill replay recovery, and
# hostile-frame rejection without session poisoning.
cargo build --release --features net
cargo test -q --features net

echo "== network transport gate (loopback-TCP bit-identity, socket-kill replay, hostile frames) =="
cargo test -q --features net --test integration_net

echo "== multi-process serve gate (real subprocesses over loopback TCP, DP release across processes) =="
cargo test -q --features net --test integration_serve

echo "== feature matrix: --features net,simd (combined) =="
cargo build --release --features net,simd
cargo test -q --features net,simd --test integration_net

echo "== fault tolerance gate (kill/restart replay bit-identity, retry exhaustion, chaos transport) =="
cargo test -q --test integration_faults
if [ "${PRIVLR_CHAOS:-0}" = "1" ]; then
    # Chaos mode: the sharded bit-identity gate re-runs under a seeded
    # random FaultPlan (drops/delays/duplicates) at N ∈ {1,2,4} shards.
    echo "== chaos mode (PRIVLR_CHAOS=1): seeded random fault plans =="
    PRIVLR_CHAOS=1 cargo test -q --test integration_faults -- --ignored
fi

# Style gates run AFTER build/test on purpose: the repo has been
# authored in toolchain-less containers, so the first real run must
# surface compile/test results even if formatting needs a one-time
# `cargo fmt` pass afterwards.
echo "== style: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "SKIP: rustfmt component not installed"
fi

echo "== style: cargo clippy -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
    cargo clippy --all-targets --features simd -- -D warnings
    cargo clippy --all-targets --features net -- -D warnings
    cargo clippy --all-targets --features net,simd -- -D warnings
else
    echo "SKIP: clippy component not installed"
fi

echo "== docs: cargo doc --no-deps (rustdoc warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [ "${PRIVLR_CI_BENCH:-0}" = "1" ]; then
    echo "== fast benches (refresh BENCH_kernels.json) =="
    PRIVLR_BENCH_FAST=1 cargo bench --bench micro_substrates
    # session_throughput also sweeps shard_scaling, fault_recovery,
    # wan_consortium (fits/sec at 0/20/80 ms injected RTT, K=16, d=10),
    # and dp_release (DP-on vs DP-off fit cost + accountant overhead).
    PRIVLR_BENCH_FAST=1 cargo bench --bench session_throughput
fi

echo "CI OK"
